"""Checkpoint delta-encoding Pallas TPU kernel (DSE-adjacent).

The paper's Fig. 10 shows persistence *bandwidth* is a first-order cost of
speculative services. For the training instantiation, successive checkpoint
versions differ by one optimizer step; this kernel block-quantizes the delta
(new - prev) to int8 with a per-block fp32 scale, cutting checkpoint bytes
~4x (bf16 -> int8 + 4B/block). The decoder fuses dequant+add on restore.

Layout: 1D parameter stream reshaped to (nblocks, block). Grid: (nblocks,).
Each block is quantized independently in VMEM: scale = max|delta| / 127.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _encode_kernel(new_ref, prev_ref, code_ref, scale_ref):
    delta = new_ref[...].astype(jnp.float32) - prev_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(delta))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    scale_ref[0] = scale
    code_ref[...] = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)


def _decode_kernel(code_ref, scale_ref, prev_ref, out_ref):
    delta = code_ref[...].astype(jnp.float32) * scale_ref[0]
    out_ref[...] = (prev_ref[...].astype(jnp.float32) + delta).astype(out_ref.dtype)


def delta_encode(
    new: jax.Array,    # (nblocks, block)
    prev: jax.Array,   # (nblocks, block)
    *,
    interpret: bool = False,
):
    nb, blk = new.shape
    return pl.pallas_call(
        _encode_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, blk), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(new, prev)


def delta_decode(
    codes: jax.Array,   # (nblocks, block) int8
    scales: jax.Array,  # (nblocks,) f32
    prev: jax.Array,    # (nblocks, block)
    dtype=jnp.bfloat16,
    *,
    interpret: bool = False,
) -> jax.Array:
    nb, blk = codes.shape
    return pl.pallas_call(
        _decode_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, blk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, blk), dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(codes, scales, prev)
