"""Flash attention Pallas TPU kernel (tiled online softmax).

TPU-native redesign of the CUDA flash algorithm: block sizes are chosen for
VMEM residency and MXU alignment (multiples of 128), not warp/shared-memory
occupancy. Grid is (batch*heads, q_blocks, kv_blocks) with the kv dimension
innermost and ARBITRARY (sequential), so the running max / denominator /
accumulator live in VMEM scratch across kv steps. Fully-masked causal
blocks are skipped via predication.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    acc_ref, m_ref, l_ref,        # scratch (f32)
    *, scale: float, causal: bool, block_q: int, block_k: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip kv blocks strictly above the diagonal band
    q_start = iq * block_q
    k_start = ik * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0].astype(jnp.float32)           # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,                  # (BH, S, D)
    k: jax.Array,                  # (BH, T, D)
    v: jax.Array,                  # (BH, T, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    bh, s, d = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    grid = (bh, s // block_q, t // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q, block_k=block_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
