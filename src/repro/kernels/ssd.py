"""Mamba-2 SSD Pallas TPU kernel.

Fuses the whole chunked-SSD pipeline for one (batch, head) pair in VMEM:
intra-chunk dense terms (the MXU-heavy L x L / L x N / L x P matmuls) AND the
inter-chunk state recurrence, carried across the sequential chunk grid
dimension in a VMEM scratch state (P, N). This avoids materializing per-chunk
states and decay matrices in HBM, which is what the pure-XLA path does.

Grid: (B, H, num_chunks) with chunks ARBITRARY (sequential).
Blocks: x (L, P), dt (L,), B/C (L, N) per chunk; y (L, P) out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _ssd_kernel(
    a_ref,                       # (1,) per-head A (negative), SMEM-ish block
    x_ref, dt_ref, b_ref, c_ref, # VMEM chunk blocks
    y_ref,                       # output chunk block
    state_ref,                   # scratch (P, N) f32: carried chunk state
    *, chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                   # scalar A_h (negative)
    x = x_ref[0, 0].astype(jnp.float32)            # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (L,)
    bm = b_ref[0, 0].astype(jnp.float32)           # (L, N)
    cm = c_ref[0, 0].astype(jnp.float32)           # (L, N)

    dA = dt * a                                    # (L,)
    cum = jnp.cumsum(dA)                           # (L,)
    # intra-chunk decay: Lmat[i, j] = exp(cum[i] - cum[j]) for j <= i
    diff = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(cols <= rows, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, L)
    gate = cb * lmat * dt[None, :]
    y = jax.lax.dot_general(
        gate, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, P) intra-chunk

    # inter-chunk: y += diag(exp(cum)) C @ state_prev^T
    prev = state_ref[...]                          # (P, N)
    y_inter = jax.lax.dot_general(
        cm, prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (L, P)
    y = y + y_inter * jnp.exp(cum)[:, None]

    # state update: state = exp(sum dA) * prev + sum_j exp(cum[-1]-cum[j]) dt_j x_j B_j^T
    decay_to_end = jnp.exp(cum[-1] - cum) * dt     # (L,)
    xw = x * decay_to_end[:, None]                 # (L, P)
    new_contrib = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (P, N)
    state_ref[...] = prev * jnp.exp(cum[-1]) + new_contrib

    y_ref[0, 0, ...] = y.astype(y_ref.dtype)


def ssd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) post-softplus
    A: jax.Array,    # (H,) negative
    Bm: jax.Array,   # (B, S, G, N) — G must divide H; expanded by the wrapper
    Cm: jax.Array,   # (B, S, G, N)
    chunk: int = 256,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, S, H, P). Head-major layout internally."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # head-major: (B, H, S, ...)
    xh = x.transpose(0, 2, 1, 3)                       # (B,H,S,P)
    dth = dt.transpose(0, 2, 1)                        # (B,H,S)
    bh = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,S,N)
    ch = jnp.repeat(Cm, rep, axis=2).transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    yh = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic)),
            pl.BlockSpec((1, 1, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc * chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(A, xh, dth, bh, ch)
    return yh.transpose(0, 2, 1, 3)                    # (B,S,H,P)
