"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """q/k/v: (BH, S|T, D) — plain softmax attention."""
    scale = scale if scale is not None else 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(q.shape[1])[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, chunk=256):
    """Sequential (non-chunked) SSD recurrence — the ground-truth oracle.
    x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,G,N); returns y (B,S,H,P)."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)   # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                               # (B,H,P), (B,H), (B,H,N) x2
        decay = jnp.exp(dtt * Af)                           # (B,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bh, 1, 0),
        jnp.moveaxis(Ch, 1, 0),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)           # (B,S,H,P)


def delta_encode_ref(new, prev):
    delta = new.astype(jnp.float32) - prev.astype(jnp.float32)
    amax = jnp.max(jnp.abs(delta), axis=1)
    scales = jnp.maximum(amax, 1e-30) / 127.0
    codes = jnp.clip(jnp.round(delta / scales[:, None]), -127, 127).astype(jnp.int8)
    return codes, scales


def delta_decode_ref(codes, scales, prev, dtype=jnp.bfloat16):
    delta = codes.astype(jnp.float32) * scales[:, None]
    return (prev.astype(jnp.float32) + delta).astype(dtype)
