"""Version shims for the Pallas TPU API."""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

# jax < 0.5 names this TPUCompilerParams; newer releases renamed it.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
