"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container), so the kernels are
validated on CPU; on TPU the same call sites compile the Mosaic kernels.
Model code selects ``attn_impl``/``ssd_impl`` in {"xla", "pallas"}; the
dry-run/roofline path uses "xla" so HLO cost analysis reflects the
production XLA pipeline (see DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import delta_encode as _de
from . import flash_attention as _fa
from . import ssd as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=None):
    """GQA flash attention. q: (B,S,Nq,H); k/v: (B,T,Nkv,H). Returns (B,S,Nq,H)."""
    interpret = _default_interpret() if interpret is None else interpret
    b, s, nq, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    if nq != nkv:
        k = jnp.repeat(k, nq // nkv, axis=2)
        v = jnp.repeat(v, nq // nkv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * nq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * nq, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * nq, t, hd)
    bq = min(block_q, s)
    bk = min(block_k, t)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, block_q=bq, block_k=bk,
                            interpret=interpret)
    return o.reshape(b, nq, s, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, chunk=256, interpret=None):
    """Mamba-2 SSD: returns y (B,S,H,P) (final state stays in-kernel)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def ssd_model_impl(x, dt, A, Bm, Cm, chunk=256):
    """Adapter matching models/ssm.py's ssd_impl signature (y, state)."""
    y = ssd(x, dt, A, Bm, Cm, chunk=chunk)
    return y, None


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_encode(new, prev, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _de.delta_encode(new, prev, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def delta_decode(codes, scales, prev, dtype=jnp.bfloat16, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _de.delta_decode(codes, scales, prev, dtype=dtype, interpret=interpret)
