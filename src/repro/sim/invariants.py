"""Machine-checked invariants for simulated DSE runs (DESIGN.md §8).

Four checkers, each phrased over artefacts a :class:`~repro.sim.cluster.SimCluster`
run produces:

* :func:`check_linearizable` — Wing–Gong linearizability over a recorded
  operation history against a sequential model (:class:`KVModel`,
  :class:`CounterModel`). Used for fault schedules that never lose
  application state (loss / delay / duplication / partitions / coordinator
  restarts): there, exactly-once transport processing must make the store
  linearizable. Crash schedules instead assert the recovery invariants
  below — the paper's guarantee for *non-barriered* state is a consistent
  prefix, not durability.
* :func:`check_exactly_once_counter` — acknowledged increments form exactly
  1..n (retries and wire duplicates never double-apply).
* :class:`WatermarkMonitor` — the recoverable boundary is monotone within a
  failure epoch (it may retreat only when the failure sequence number
  advances).
* :func:`check_shard_logs` — per-shard durable logs are prefix-consistent:
  decision fsns strictly increase per log, every pair of logs agrees
  byte-for-byte on any fsn they share, and at quiescence every live shard
  log replicates every decision.
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


class InvariantViolation(AssertionError):
    """An invariant checker found a counterexample."""


# --------------------------------------------------------------------------- #
# operation histories                                                          #
# --------------------------------------------------------------------------- #
class _Pending:
    def __repr__(self) -> str:
        return "<pending>"


#: result sentinel for an operation whose response was never observed (call
#: timed out / crashed mid-flight): it may have taken effect or not.
PENDING = _Pending()


@dataclass
class Op:
    client: str
    method: str
    args: Tuple
    result: object
    invoked: float
    returned: Optional[float]  # None => pending (effect unknown)

    @property
    def completed(self) -> bool:
        return self.returned is not None

    def __repr__(self) -> str:
        span = f"[{self.invoked:.4f},{self.returned:.4f}]" if self.completed else f"[{self.invoked:.4f},?)"
        return f"{self.client}:{self.method}{self.args}->{self.result!r}{span}"


class KVModel:
    """Sequential specification of SpeculativeKVStore's service API."""

    initial: Tuple = ()

    @staticmethod
    def apply(state: Tuple, op: Op) -> Tuple[Tuple, object]:
        d = dict(state)
        if op.method == "put":
            key, value = op.args[0], op.args[1]
            d[key] = value
            result: object = "ok"
        elif op.method == "get":
            return state, d.get(op.args[0])
        elif op.method == "delete":
            d.pop(op.args[0], None)
            result = "ok"
        else:
            raise ValueError(f"KVModel cannot apply {op.method!r}")
        return tuple(sorted(d.items())), result


class CounterModel:
    """Sequential specification of CounterStateObject.increment."""

    initial: int = 0

    @staticmethod
    def apply(state: int, op: Op) -> Tuple[int, object]:
        if op.method != "increment":
            raise ValueError(f"CounterModel cannot apply {op.method!r}")
        by = op.args[0] if op.args else 1
        return state + by, state + by


def check_linearizable(history: Sequence[Op], model=KVModel, max_states: int = 2_000_000):
    """Wing–Gong search: find a total order of the operations consistent with
    real-time (an op that completed before another was invoked must come
    first) under which the sequential ``model`` reproduces every recorded
    result. Pending ops may linearize anywhere after their invocation or
    never. Returns None if linearizable, else a human-readable explanation.
    """
    ops = list(history)
    n = len(ops)
    completed = [i for i in range(n) if ops[i].completed]
    # search state: frozenset of applied op indices + model state
    seen = set()
    explored = 0

    def minimal(applied: frozenset) -> List[int]:
        """Ops whose invocation is not preceded by an unapplied completed op's
        return — the only legal next linearization points."""
        floor = min(
            (ops[i].returned for i in completed if i not in applied),
            default=float("inf"),
        )
        return [
            i
            for i in range(n)
            if i not in applied and ops[i].invoked <= floor
        ]

    stack: List[Tuple[frozenset, object]] = [(frozenset(), model.initial)]
    while stack:
        applied, state = stack.pop()
        if all(i in applied for i in completed):
            return None  # every completed op linearized: success
        key = (applied, state)
        if key in seen:
            continue
        seen.add(key)
        explored += 1
        if explored > max_states:
            return (
                f"linearizability search exceeded {max_states} states "
                f"({n} ops) — treat as failure and shrink the scenario"
            )
        for i in minimal(applied):
            op = ops[i]
            try:
                new_state, result = model.apply(state, op)
            except ValueError:
                return f"model cannot apply {op!r}"
            if op.completed and op.result is not PENDING and result != op.result:
                continue  # this linearization point contradicts the response
            stack.append((applied | {i}, new_state))
    # no order worked: report the smallest suspicious completed op set
    return (
        "history is NOT linearizable: no valid total order for "
        + "; ".join(repr(ops[i]) for i in completed[:8])
        + (" ..." if len(completed) > 8 else "")
    )


# --------------------------------------------------------------------------- #
# exactly-once effects                                                         #
# --------------------------------------------------------------------------- #
def check_exactly_once_counter(acks: Sequence[int], final_value: int) -> Optional[str]:
    """Acknowledged increment results must be exactly 1..n and the final
    counter must equal n: a retried or wire-duplicated increment that
    double-applied would produce a gap / repeat / overshoot."""
    n = len(acks)
    if sorted(acks) != list(range(1, n + 1)):
        dupes = sorted({a for a in acks if list(acks).count(a) > 1})
        return f"acks are not a permutation of 1..{n} (duplicates={dupes}, acks={sorted(acks)[:20]})"
    if final_value != n:
        return f"final counter {final_value} != {n} acknowledged increments"
    return None


# --------------------------------------------------------------------------- #
# monotone watermarks                                                          #
# --------------------------------------------------------------------------- #
class WatermarkMonitor:
    """Samples (fsn, recoverable boundary) over virtual time and checks the
    boundary is monotone within each failure epoch."""

    def __init__(self) -> None:
        self.samples: List[Tuple[float, int, Optional[Dict[str, int]]]] = []

    def sample(self, at: float, fsn: int, boundary: Optional[Dict[str, int]]) -> None:
        self.samples.append((at, fsn, dict(boundary) if boundary is not None else None))

    def check(self) -> List[str]:
        errors: List[str] = []
        prev_fsn = -1
        prev_b: Dict[str, int] = {}
        for at, fsn, boundary in self.samples:
            if fsn < prev_fsn:
                errors.append(f"t={at:.4f}: fsn went backwards {prev_fsn}->{fsn}")
            if boundary is None:  # coordinator recovering: no claim made
                prev_fsn = max(prev_fsn, fsn)
                continue
            if fsn == prev_fsn:
                for so, wm in prev_b.items():
                    if boundary.get(so, -1) < wm:
                        errors.append(
                            f"t={at:.4f}: boundary[{so}] retreated "
                            f"{wm}->{boundary.get(so, -1)} within epoch {fsn}"
                        )
            prev_fsn = max(prev_fsn, fsn)
            prev_b = boundary
        return errors


# --------------------------------------------------------------------------- #
# per-shard durable-log prefix consistency                                     #
# --------------------------------------------------------------------------- #
def _durable_decisions(base: Path) -> Tuple[int, List[dict]]:
    """The decision records a (possibly snapshot-rotated) coordinator log
    durably holds, in replay order, plus its ``retired_upto`` watermark:
    snapshot-retained decisions first, then the JSONL suffix (torn tail
    writes tolerated by ``read_durable_log``, same as recovery itself)."""
    from repro.store import decode_snapshot, read_durable_log

    retired = 0
    _, blob, records = read_durable_log(base)
    out: List[dict] = []
    if blob is not None:
        snap = decode_snapshot(blob)
        retired = snap.retired_upto
        out += [{"type": "decision", **d.to_json()} for d in snap.decisions]
    out += [r for r in records if r.get("type") == "decision"]
    return retired, out


def check_shard_logs(coord_root: Path) -> List[str]:
    """Prefix-consistency of the coordinator's durable logs (module docstring).
    Works on a sharded root (``shard*.jsonl`` bases, rotated or not) or a
    singleton log path. Retirement-aware (DESIGN.md §11): a decision absent
    from a log is only an error if that log has NOT retired it — a shard
    whose compactor proved the decision dead is allowed to forget it."""
    coord_root = Path(coord_root)
    if coord_root.is_file() or coord_root.with_name(
        coord_root.name + ".manifest"
    ).exists():
        bases = [coord_root]
    else:
        # a rotated shard's base file is gone — discover via manifests too
        found = set(coord_root.glob("shard*.jsonl"))
        found |= {
            p.with_name(p.name[: -len(".manifest")])
            for p in coord_root.glob("shard*.jsonl.manifest")
        }
        bases = sorted(found)
    errors: List[str] = []
    decisions_by_log: Dict[str, Dict[int, dict]] = {}
    retired_by_log: Dict[str, int] = {}
    for base in bases:
        name = base.name
        retired_by_log[name], records = _durable_decisions(base)
        fsns: List[int] = []
        per: Dict[int, dict] = {}
        for rec in records:
            fsn = int(rec["fsn"])
            fsns.append(fsn)
            per[fsn] = rec
        for a, b in zip(fsns, fsns[1:]):
            if b <= a:
                errors.append(f"{name}: decision fsns not strictly increasing ({a} then {b})")
        decisions_by_log[name] = per
    # pairwise agreement + replication completeness at quiescence
    all_fsns = sorted({f for per in decisions_by_log.values() for f in per})
    names = sorted(decisions_by_log)
    for fsn in all_fsns:
        seen_rec: Optional[Tuple[str, dict]] = None
        for name in names:
            rec = decisions_by_log[name].get(fsn)
            if rec is None:
                if fsn > retired_by_log[name]:
                    errors.append(f"{name}: missing broadcast decision fsn={fsn}")
                continue
            if seen_rec is None:
                seen_rec = (name, rec)
            elif rec != seen_rec[1]:
                errors.append(
                    f"decision fsn={fsn} differs between {seen_rec[0]} and {name}: "
                    f"{seen_rec[1]} != {rec}"
                )
    return errors
