"""Seed-sweep driver + fault-plan shrinker (DESIGN.md §8).

``python -m repro.sim.explore --scenario kv --scenario workflow --seeds 100``
runs N seeds of each named scenario under deterministic simulation; each
seed derives the client op scripts AND the fault schedule, so a failure is
reproducible from ``(scenario, seed)``. On the first failure the driver
ddmin-shrinks the fault plan to a minimal still-failing repro and writes a
JSON artifact (scenario, seed, shrunk plan, error) — CI uploads it, and the
pinned-seed regression suite (``tests/test_sim_scenarios.py``) replays it
forever after.

Scenarios (registry ``SCENARIOS``):

* ``kv``        — concurrent clients against SpeculativeKVStore under benign
                  faults (loss/dup/delay/partition/shard restarts); must be
                  linearizable, watermarks monotone, shard logs consistent.
* ``counter``   — producer→consumer chain under crash-restarts; consistent
                  prefix + durable-floor survival + exactly-once acks.
* ``workflow``  — WorkflowEngine driving KV steps over the faulty fabric;
                  workflows complete with exactly-once step effects.
* ``crash_commit`` / ``partition_merge`` / ``dup_fragments`` — the pinned
  regression scenarios (explicit fault plans at nasty protocol moments).
* ``broker``    — produce→consume→ack pipeline over the speculative event
                  broker under benign faults; exactly-once in-order delivery.
* ``two_phase_commit`` — transactional client over TwoPC under crashes +
                  partitions; acked commits are durable + atomic everywhere.
* ``differential_kv`` / ``differential_workflow`` — the differential oracle
  (``sim/differential.py``): one seeded history + fault plan replayed on
  both the DSE and the synchronous durable runtime; committed results must
  match op-for-op (durable = oracle).
* ``snapshot_recovery_kv`` / ``snapshot_recovery_workflow`` — the
  snapshot-vs-replay oracle (DESIGN.md §11): one seeded history + long-
  horizon crash/restart plan with interleaved checkpoints, replayed on a
  compaction-armed cluster and a full-replay cluster; recovery from
  snapshot+suffix must be observationally identical to full replay.
"""
from __future__ import annotations

import argparse
import json
import random
import shutil
import sys
import tempfile
import time
from functools import partial
from pathlib import Path
from typing import Callable, Dict, List, Optional

from ..net import LinkSpec
from .cluster import RecordingClient, SimCluster, SimResult
from .differential import (
    default_differential_plan,
    default_snapshot_plan,
    differential_kv_scenario,
    differential_workflow_scenario,
    snapshot_recovery_kv_scenario,
    snapshot_recovery_workflow_scenario,
)
from .faults import FaultPlan
from .invariants import (
    CounterModel,
    InvariantViolation,
    KVModel,
    check_exactly_once_counter,
    check_linearizable,
    check_shard_logs,
)

Scenario = Callable[[int, Path, Optional[FaultPlan]], SimResult]


def default_plan(scenario: str, seed: int) -> FaultPlan:
    """The fault schedule a scenario runs when no explicit plan is passed —
    the single source of truth, so ``sweep()`` shrinks exactly the plan the
    failing run executed (a regenerated plan with different RNG draws would
    never reproduce the failure)."""
    if scenario == "kv":
        return FaultPlan.random(
            seed, so_ids=["kv"], horizon=1.0, n_shards=2, allow_crash=False
        )
    if scenario == "counter":
        return FaultPlan.random(
            seed, so_ids=["prod", "cons"], horizon=0.8, n_shards=2, allow_crash=True
        )
    if scenario == "workflow":
        return FaultPlan.random(
            seed, so_ids=["kv", "wf"], horizon=0.8, n_shards=2, allow_crash=False
        )
    if scenario == "broker":
        return FaultPlan.random(
            seed, so_ids=["broker"], horizon=0.8, n_shards=2, allow_crash=False
        )
    if scenario == "two_phase_commit":
        return FaultPlan.random(
            seed,
            so_ids=["coord2pc", "p0", "p1"],
            horizon=0.8,
            n_shards=2,
            allow_crash=True,
        )
    if scenario in ("differential_kv", "differential_workflow"):
        return default_differential_plan(seed)
    if scenario in ("snapshot_recovery_kv", "snapshot_recovery_workflow"):
        return default_snapshot_plan(seed)
    if scenario == "crash_commit":
        return FaultPlan().crash(0.055, "prod")  # mid group-commit interval
    if scenario == "partition_merge":
        return FaultPlan().partition(0.03, ["coord/0", "coord/1"]).heal(0.25)
    if scenario == "dup_fragments":
        return (
            FaultPlan()
            .method_link(0.02, "report", loss_prob=0.2, dup_prob=0.6, latency_ms=1.0)
            .method_link(
                0.02, "receive_fragments", loss_prob=0.2, dup_prob=0.6, latency_ms=2.0
            )
            .restart_coordinator(0.12)
            .clear_method_link(0.6, "report")
            .clear_method_link(0.6, "receive_fragments")
        )
    raise KeyError(f"unknown scenario {scenario!r}")


def _raise_if(errors: List[str], seed: int, name: str) -> None:
    errors = [e for e in errors if e]
    if errors:
        raise InvariantViolation(
            f"[{name} seed={seed}] " + " | ".join(str(e) for e in errors)
        )


# --------------------------------------------------------------------------- #
# kv: linearizability under benign faults                                      #
# --------------------------------------------------------------------------- #
def kv_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    from ..services.kv_store import SpeculativeKVStore

    horizon = 1.0  # matches default_plan("kv", ...)
    if plan is None:
        plan = default_plan("kv", seed)
    rng = random.Random(seed ^ 0x5EEDFACE)
    keys = ["a", "b", "c"]
    scripts = [
        [
            (
                rng.choice(["put", "get", "get", "delete"]),
                rng.choice(keys),
                f"v{rng.randrange(50)}",
                rng.uniform(0.0, 0.04),
            )
            for _ in range(12)
        ]
        for _ in range(3)
    ]
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
    )

    def scenario(sim: SimCluster):
        sim.add("kv", lambda: SpeculativeKVStore(sim.root / "so_kv"))

        def client(i: int) -> None:
            cli = RecordingClient(sim, "kv", f"cli{i}")
            for method, key, value, pause in scripts[i]:
                if method == "put":
                    cli.put(key, value)
                elif method == "delete":
                    cli.delete(key)
                else:
                    cli.get(key)
                sim.sleep(pause)

        tasks = [sim.spawn(partial(client, i), name=f"cli{i}") for i in range(3)]
        for t in tasks:
            t.join()
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)  # outlive the plan
        sim.settle(lambda: sim.boundary() is not None, timeout=20.0)

    result = sim.run(scenario, plan=plan)
    errors: List[str] = []
    lin = check_linearizable(result.history, KVModel)
    if lin:
        errors.append(lin)
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "kv")
    return result


# --------------------------------------------------------------------------- #
# counter: crash-restarts => consistent prefix                                 #
# --------------------------------------------------------------------------- #
def counter_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    from ..services.counter import CounterStateObject

    horizon = 0.8  # matches default_plan("counter", ...)
    if plan is None:
        plan = default_plan("counter", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.02,
        call_timeout=10.0,
    )
    rng = random.Random(seed ^ 0xC0FFEE)
    pauses = [rng.uniform(0.0, 0.05) for _ in range(16)]

    def scenario(sim: SimCluster):
        sim.add("prod", lambda: CounterStateObject(sim.root / "so_prod"))
        sim.add("cons", lambda: CounterStateObject(sim.root / "so_cons"))
        for pause in pauses:
            try:
                res = sim.send(None, "prod", "increment", None)
                if res is not None:
                    _, h = res
                    sim.send(None, "cons", "increment", h)
            except TimeoutError:
                pass  # crash/partition window: the chain just thins out
            sim.sleep(pause)
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)
        # settle: one world, boundary served for both members
        ok = sim.settle(
            lambda: (
                sim.get("prod").runtime.world == sim.get("cons").runtime.world
                and sim.boundary() is not None
            ),
            timeout=30.0,
        )
        return {
            "converged": ok,
            "prod": sim.get("prod").value,
            "cons": sim.get("cons").value,
            "worlds": (sim.get("prod").runtime.world, sim.get("cons").runtime.world),
        }

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    if not v["converged"]:
        errors.append(f"cluster failed to converge: {v}")
    if v["cons"] > v["prod"]:
        errors.append(
            f"consistent-prefix violation: consumer {v['cons']} > producer {v['prod']}"
        )
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "counter")
    return result


# --------------------------------------------------------------------------- #
# workflow: engine-driven steps over the faulty fabric                         #
# --------------------------------------------------------------------------- #
def workflow_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    from ..services.kv_store import SpeculativeKVStore
    from ..services.workflow import WorkflowEngine

    horizon = 0.8  # matches default_plan("workflow", ...)
    if plan is None:
        plan = default_plan("workflow", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
    )
    n_workflows, n_steps = 3, 3

    def scenario(sim: SimCluster):
        sim.add("kv", lambda: SpeculativeKVStore(sim.root / "so_kv"))
        sim.add("wf", lambda: WorkflowEngine(sim.root / "so_wf"))
        sim.send(None, "kv", "stock", "seat", n_workflows * n_steps, None)
        wf = sim.get("wf")
        outcomes = {}

        def steps(wf_id: str):
            return [
                (lambda h, i=i: sim.send("wf", "kv", "try_reserve", "seat", f"{wf_id}:{i}", h))
                for i in range(n_steps)
            ]

        def drive(wf_id: str) -> None:
            for _ in range(50):  # driver retries on rollback/discard
                try:
                    out = wf.run_workflow(wf_id, steps(wf_id))
                except TimeoutError:
                    out = None
                if out is not None:
                    outcomes[wf_id] = out[0]
                    return
                sim.sleep(0.02)
            outcomes[wf_id] = None

        tasks = [
            sim.spawn(partial(drive, f"wf{i}"), name=f"wf-driver{i}")
            for i in range(n_workflows)
        ]
        for t in tasks:
            t.join()
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)
        sim.settle(lambda: sim.boundary() is not None, timeout=20.0)
        left = sim.send(None, "kv", "get", "inv:seat", None)
        return {"outcomes": outcomes, "left": left[0] if left else None}

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    for wf_id, out in v["outcomes"].items():
        if out is None:
            errors.append(f"{wf_id} never completed")
        elif out != [True] * n_steps:
            errors.append(f"{wf_id} step results {out} != all-success")
    # exactly-once step effects: every reservation decremented inventory once
    if v["left"] != "0":
        errors.append(
            f"inventory {v['left']!r} != '0' after {n_workflows * n_steps} reserves "
            "(a retried/duplicated step double-applied, or one was lost)"
        )
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "workflow")
    return result


# --------------------------------------------------------------------------- #
# pinned regression scenarios (explicit plans at nasty protocol moments)       #
# --------------------------------------------------------------------------- #
def crash_commit_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    """Producer crashes in the middle of a group-commit window: the consumer
    must roll back to the producer's surviving prefix, never past it, and
    the barriered durable floor must survive."""
    from ..services.counter import CounterStateObject

    if plan is None:
        plan = default_plan("crash_commit", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.002,
        group_commit_interval=0.02,
        call_timeout=10.0,
    )

    def scenario(sim: SimCluster):
        sim.add("prod", lambda: CounterStateObject(sim.root / "so_prod"))
        sim.add("cons", lambda: CounterStateObject(sim.root / "so_cons"))
        h = None
        acks = []
        for _ in range(3):  # durable prefix, barriered
            v, h = sim.send(None, "prod", "increment", None)
            acks.append(v)
            sim.send(None, "cons", "increment", h)
        sim.get("prod").runtime.maybe_persist(force=True)
        t = sim.get("cons").Detach()
        t.Barrier(timeout=20.0)
        assert sim.get("cons").Merge(t)
        sim.get("cons").EndAction()
        durable = sim.get("cons").value
        # speculative tail racing the crash at t=0.055
        deadline = sim.clock.now() + 0.2
        while sim.clock.now() < deadline:
            try:
                res = sim.send(None, "prod", "increment", None)
                if res is not None:
                    sim.send(None, "cons", "increment", res[1])
            except Exception:  # noqa: BLE001 — crash window: timeout,
                break  # CrashedError, or transport error all end the tail
            sim.sleep(0.01)
        sim.settle(
            lambda: sim.get("prod").runtime.world >= 1
            and sim.get("cons").runtime.world == sim.get("prod").runtime.world,
            timeout=30.0,
        )
        return {
            "durable": durable,
            "prod": sim.get("prod").value,
            "cons": sim.get("cons").value,
            "worlds": (sim.get("prod").runtime.world, sim.get("cons").runtime.world),
        }

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    if v["worlds"][0] < 1 or v["worlds"][0] != v["worlds"][1]:
        errors.append(f"worlds did not converge past the failure: {v['worlds']}")
    if v["cons"] > v["prod"]:
        errors.append(f"consumer {v['cons']} ahead of producer {v['prod']}")
    if v["prod"] < v["durable"] or v["cons"] < v["durable"]:
        errors.append(f"barriered durable floor {v['durable']} lost: {v}")
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "crash_commit")
    return result


def partition_merge_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    """Coordinator shards partitioned away exactly while cross-shard traffic
    is creating inter-shard dependencies; after healing, the cross-shard
    boundary fixpoint must converge and stay monotone."""
    from ..services.counter import CounterStateObject

    if plan is None:
        plan = default_plan("partition_merge", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=10.0,
    )

    def scenario(sim: SimCluster):
        def pick_ids():
            # two so_ids that consistent-hash to DIFFERENT shards, so the
            # dependency chain crosses the boundary-fixpoint exchange
            ring = sim.cluster.coordinator
            first = "p0"
            home = ring.shard_index(first)
            for i in range(1, 500):
                if ring.shard_index(f"p{i}") != home:
                    return first, f"p{i}"
            raise AssertionError("ring maps everything to one shard")

        p_id, q_id = pick_ids()
        sim.add(p_id, lambda: CounterStateObject(sim.root / "so_p"))
        sim.add(q_id, lambda: CounterStateObject(sim.root / "so_q"))
        acks = []
        timeouts = 0
        for _ in range(8):  # cross-shard dependency chain spanning the cut
            try:
                v, h = sim.send(None, p_id, "increment", None)
                acks.append(v)
                sim.send(None, q_id, "increment", h)
            except TimeoutError:
                timeouts += 1  # the increment may still have applied (pending)
            sim.sleep(0.05)
        sim.settle(
            lambda: all(
                (sim.boundary() or {}).get(so, -1) >= 1 for so in (p_id, q_id)
            ),
            timeout=30.0,
        )
        return {
            "acks": acks,
            "timeouts": timeouts,
            "final": sim.get(p_id).value,
            "boundary": sim.boundary(),
            "ids": (p_id, q_id),
        }

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    b = v["boundary"] or {}
    for so in v["ids"]:
        if b.get(so, -1) < 1:
            errors.append(f"boundary never converged for {so}: {b}")
    if v["timeouts"] == 0:
        # no pending ops: the producer's real final value must equal the
        # ack count — a retried/duplicated increment that double-applied
        # without a duplicate ack shows up here, not in the ack list
        eo = check_exactly_once_counter(v["acks"], v["final"])
        if eo:
            errors.append(eo)
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "partition_merge")
    return result


def dup_fragments_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    """Coordinator restarts while the fabric duplicates + drops fragment
    resends and reports: recovery must converge to a view at least as fresh
    as pre-failure, with no duplicated decisions in any shard log."""
    from ..services.counter import CounterStateObject

    if plan is None:
        plan = default_plan("dup_fragments", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=10.0,
    )

    def scenario(sim: SimCluster):
        sim.add("a", lambda: CounterStateObject(sim.root / "so_a"))
        sim.add("b", lambda: CounterStateObject(sim.root / "so_b"))
        acks = []
        h = None
        for _ in range(6):
            v, h = sim.send(None, "a", "increment", h)
            acks.append(v)
            sim.send(None, "b", "increment", h)
            sim.sleep(0.02)
        sim.settle(lambda: (sim.boundary() or {}).get("a", -1) >= 1, timeout=20.0)
        before = dict(sim.boundary() or {})
        sim.sleep(0.2)  # ride through the restart at t=0.12
        sim.settle(lambda: sim.boundary() is not None, timeout=30.0)
        after = dict(sim.boundary() or {})
        # keep serving in the recovered view
        v, h = sim.send(None, "a", "increment", h)
        acks.append(v)
        return {
            "before": before,
            "after": after,
            "acks": acks,
            "final": sim.get("a").value,
        }

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    for so, wm in v["before"].items():
        if v["after"].get(so, -1) < wm:
            errors.append(
                f"recovered boundary[{so}]={v['after'].get(so, -1)} < pre-failure {wm}"
            )
    # real final value, not len(acks): catches a duplicated fragment/report
    # double-applying an increment whose ack list still looks clean
    eo = check_exactly_once_counter(v["acks"], v["final"])
    if eo:
        errors.append(eo)
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "dup_fragments")
    return result


# --------------------------------------------------------------------------- #
# broker: produce -> consume -> ack, exactly-once in order                      #
# --------------------------------------------------------------------------- #
def broker_scenario(seed: int, root: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    """DARQ-style pipeline over the speculative event broker under benign
    fabric faults (loss / dup / delay / partitions / shard restarts): every
    produced event is consumed exactly once, in order, and the ack offset
    only advances past consumed prefixes."""
    from ..services.broker import EventBroker

    horizon = 0.8  # matches default_plan("broker", ...)
    if plan is None:
        plan = default_plan("broker", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
    )
    rng = random.Random(seed ^ 0xB40CE4)
    n_events = 12
    pauses = [rng.uniform(0.0, 0.05) for _ in range(n_events)]

    def scenario(sim: SimCluster):
        sim.add("broker", lambda: EventBroker(sim.root / "so_broker", topics=["t"]))
        # offset -> data: the broker redelivers unacked events by contract
        # (at-least-once consume + ack-advances-offset), so the consumer is
        # idempotent by offset — conflicting data for one offset is the bug.
        consumed: Dict[int, bytes] = {}
        conflicts: List[str] = []

        def producer() -> None:
            for i, pause in enumerate(pauses):
                try:
                    sim.send(None, "broker", "produce", "t", [f"e{i}".encode()], None)
                except TimeoutError:
                    pass  # unreachable in practice: call_timeout outlives
                    # every partition window and the fabric retries
                sim.sleep(pause)

        def consumer() -> None:
            deadline = sim.clock.now() + 30.0
            while len(consumed) < n_events and sim.clock.now() < deadline:
                try:
                    out = sim.send(None, "broker", "consume", "g", "t", 4, None)
                    if out is not None:
                        events, h = out
                        for off, data in events:
                            if consumed.setdefault(off, data) != data:
                                conflicts.append(f"offset {off} redelivered different data")
                        if events:
                            sim.send(None, "broker", "ack", "g", "t", events[-1][0], h)
                except TimeoutError:
                    pass
                sim.sleep(0.02)

        tasks = [
            sim.spawn(producer, name="producer"),
            sim.spawn(consumer, name="consumer"),
        ]
        for t in tasks:
            t.join()
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)
        sim.settle(lambda: sim.boundary() is not None, timeout=20.0)
        broker = sim.get("broker")
        return {
            "consumed": [consumed[k] for k in sorted(consumed)],
            "conflicts": conflicts,
            "tail": broker.topic_tail("t"),
            "skipped": broker.entries_skipped(),
        }

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = list(v["conflicts"])
    expected = [f"e{i}".encode() for i in range(n_events)]
    if v["consumed"] != expected:
        errors.append(
            f"exactly-once in-order consumption violated: got {v['consumed']!r}"
        )
    if v["tail"] != n_events:
        errors.append(f"topic tail {v['tail']} != {n_events} produced (dup/lost produce)")
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "broker")
    return result


# --------------------------------------------------------------------------- #
# two_phase_commit: atomic commit under crashes + partitions                    #
# --------------------------------------------------------------------------- #
def two_phase_commit_scenario(
    seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """Transactional client over speculative 2PC while participants crash
    and the fabric partitions: every client-acked commit must be durable in
    every participant's log after recovery, and no transaction may commit
    in one participant and abort in another."""
    from ..services.two_phase_commit import TwoPCClient, TwoPCCoordinator, TwoPCParticipant

    horizon = 0.8  # matches default_plan("two_phase_commit", ...)
    if plan is None:
        plan = default_plan("two_phase_commit", seed)
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
    )
    rng = random.Random(seed ^ 0x2FC0)
    n_txns = 5
    pauses = [rng.uniform(0.0, 0.06) for _ in range(n_txns)]

    def scenario(sim: SimCluster):
        from ..core.runtime import CrashedError

        sim.add("coord2pc", lambda: TwoPCCoordinator(sim.root / "so_c2pc"))
        for i in range(2):
            sim.add(f"p{i}", (lambda i=i: TwoPCParticipant(sim.root / f"so_p{i}")))
        from ..core.sthread import RolledBackError

        acked: List[str] = []
        for i, pause in enumerate(pauses):
            # fresh txn id per ATTEMPT: a retry after a rollback mid-protocol
            # must not reuse an id that may already carry a (lost-then-
            # durable) decide record — real clients retry with new ids too.
            for attempt in range(60):
                txn = f"t{i}a{attempt}"
                try:
                    # re-fetch every attempt — crash faults replace incarnations
                    client = TwoPCClient(
                        sim.get("coord2pc"), [sim.get("p0"), sim.get("p1")]
                    )
                    out = client.run(txn)
                except (TimeoutError, CrashedError, RolledBackError):
                    out = None
                if out:  # acked commit; False (abort) retries with a new id
                    acked.append(txn)
                    break
                sim.sleep(0.02)
            sim.sleep(pause)
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)
        sim.settle(
            lambda: sim.boundary() is not None
            and len(
                {sim.get(s).runtime.world for s in ("coord2pc", "p0", "p1")}
            )
            == 1,
            timeout=30.0,
        )
        logs = {}
        for s in ("p0", "p1"):
            entries = [e.decode() for _, e in sim.get(s).core.scan(0)]
            logs[s] = entries
        return {"acked": acked, "logs": logs}

    result = sim.run(scenario, plan=plan)
    v = result.value
    errors: List[str] = []
    for s, entries in v["logs"].items():
        decided = {}
        for e in entries:
            parts = e.split(":")
            if parts[0] == "decide":
                txn, verdict = parts[1], parts[2]
                if decided.get(txn, verdict) != verdict:
                    errors.append(f"{s}: {txn} both committed and aborted: {entries}")
                decided[txn] = verdict
        for txn in v["acked"]:
            if decided.get(txn) != "c":
                errors.append(
                    f"client-acked commit {txn} not durable in {s} (decided={decided})"
                )
    # atomicity across participants: no txn decided differently in p0 vs p1
    def _decisions_of(entries):
        return {
            e.split(":")[1]: e.split(":")[2] for e in entries if e.startswith("decide:")
        }

    d0, d1 = _decisions_of(v["logs"]["p0"]), _decisions_of(v["logs"]["p1"])
    for txn in set(d0) & set(d1):
        if d0[txn] != d1[txn]:
            errors.append(f"atomicity violated for {txn}: p0={d0[txn]} p1={d1[txn]}")
    errors += result.watermarks.check()
    errors += check_shard_logs(root / "cluster" / "coord")
    _raise_if(errors, seed, "two_phase_commit")
    return result


SCENARIOS: Dict[str, Scenario] = {
    "kv": kv_scenario,
    "counter": counter_scenario,
    "workflow": workflow_scenario,
    "crash_commit": crash_commit_scenario,
    "partition_merge": partition_merge_scenario,
    "dup_fragments": dup_fragments_scenario,
    "broker": broker_scenario,
    "two_phase_commit": two_phase_commit_scenario,
    "differential_kv": differential_kv_scenario,
    "differential_workflow": differential_workflow_scenario,
    "snapshot_recovery_kv": snapshot_recovery_kv_scenario,
    "snapshot_recovery_workflow": snapshot_recovery_workflow_scenario,
}


# --------------------------------------------------------------------------- #
# sweep + shrink                                                               #
# --------------------------------------------------------------------------- #
def run_one(scenario: str, seed: int, workdir: Path, plan: Optional[FaultPlan] = None) -> SimResult:
    fn = SCENARIOS[scenario]
    Path(workdir).mkdir(parents=True, exist_ok=True)
    root = Path(tempfile.mkdtemp(prefix=f"{scenario}-{seed}-", dir=workdir))
    try:
        return fn(seed, root, plan)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def shrink(
    scenario: str,
    seed: int,
    plan: FaultPlan,
    workdir: Path,
    max_runs: int = 60,
    match_error: Optional[str] = None,
    deadline: Optional[float] = None,
) -> FaultPlan:
    """ddmin over fault events: repeatedly delete chunks while the scenario
    still fails. Client op scripts stay pinned to the seed, so only the
    fault schedule shrinks. ``match_error`` (an exception class name) keeps
    the shrink honest: a candidate only counts as failing if it fails the
    same WAY — otherwise deleting a load-bearing fault can swap one failure
    for a different one and the "minimal" plan reproduces the wrong bug.
    ``deadline`` (``time.time()`` epoch) stops shrinking when the caller's
    wall budget runs out — the current best (possibly unshrunk) plan is
    still a valid repro, and writing SOME artifact beats being killed by
    the CI job timeout mid-shrink with none."""
    runs = 0

    def fails(p: FaultPlan) -> bool:
        nonlocal runs
        if runs >= max_runs:
            return False
        if deadline is not None and time.time() >= deadline:
            return False
        runs += 1
        try:
            run_one(scenario, seed, workdir, plan=p)
            return False
        except Exception as e:  # noqa: BLE001 — compared, not swallowed
            return match_error is None or type(e).__name__ == match_error

    current = plan
    chunk = max(1, len(current.sorted_events()) // 2)
    while chunk >= 1:
        i = 0
        while i < len(current.sorted_events()):
            cand = current.without(range(i, i + chunk))
            if cand.events != current.events and fails(cand):
                current = cand
            else:
                i += chunk
        if chunk == 1:
            break
        chunk = max(1, chunk // 2)
    return current


def sweep(
    scenarios: List[str],
    n_seeds: int,
    *,
    start_seed: int = 0,
    budget_s: float = 600.0,
    out: Optional[Path] = None,
    workdir: Optional[Path] = None,
) -> int:
    """Run ``n_seeds`` of each scenario inside a wall-clock budget; on the
    first failure, shrink its plan and write the repro artifact. Returns the
    process exit code."""
    workdir = Path(workdir or tempfile.mkdtemp(prefix="sim-sweep-"))
    workdir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    ran = 0
    for scenario in scenarios:
        for seed in range(start_seed, start_seed + n_seeds):
            if time.time() - t0 > budget_s:
                print(
                    f"budget {budget_s}s exhausted after {ran} runs "
                    f"({ran / max(time.time() - t0, 1e-9):.1f} seeds/s)",
                    flush=True,
                )
                return 0
            try:
                result = run_one(scenario, seed, workdir)
                ran += 1
                if seed % 10 == 0:
                    print(
                        f"[{scenario}] seed={seed} ok "
                        f"({result.events} events, {result.virtual_time:.2f} vs)",
                        flush=True,
                    )
            except Exception as e:  # noqa: BLE001 — every failure is a repro
                print(f"[{scenario}] seed={seed} FAILED: {e}", flush=True)
                shrunk = shrink(
                    scenario,
                    seed,
                    default_plan(scenario, seed),
                    workdir,
                    match_error=type(e).__name__,
                    deadline=t0 + budget_s,  # shrink inside the same budget
                )
                artifact = {
                    "scenario": scenario,
                    "seed": seed,
                    "error": repr(e),
                    "plan": shrunk.to_json(),
                    "hint": (
                        "repro: python -m repro.sim.explore "
                        f"--scenario {scenario} --seeds 1 --start-seed {seed}; "
                        "pin it in tests/scenarios/regression_seeds.json"
                    ),
                }
                if out is not None:
                    out.parent.mkdir(parents=True, exist_ok=True)
                    out.write_text(json.dumps(artifact, indent=2))
                    print(f"shrunk fault plan written to {out}", flush=True)
                else:
                    print(json.dumps(artifact, indent=2), flush=True)
                return 1
    dt = max(time.time() - t0, 1e-9)
    print(f"{ran} runs green in {dt:.1f}s ({ran / dt:.1f} seeds/s)", flush=True)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--scenario",
        action="append",
        choices=sorted(SCENARIOS),
        help="scenario name (repeatable); default: kv",
    )
    ap.add_argument("--seeds", type=int, default=20)
    ap.add_argument("--start-seed", type=int, default=0)
    ap.add_argument("--budget", type=float, default=600.0, help="wall-clock seconds")
    ap.add_argument("--out", type=Path, default=None, help="failure artifact path")
    ap.add_argument("--workdir", type=Path, default=None)
    args = ap.parse_args(argv)
    return sweep(
        args.scenario or ["kv"],
        args.seeds,
        start_seed=args.start_seed,
        budget_s=args.budget,
        out=args.out,
        workdir=args.workdir,
    )


if __name__ == "__main__":
    sys.exit(main())
