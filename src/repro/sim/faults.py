"""Seeded fault schedules (DESIGN.md §8): the ``FaultPlan`` DSL.

A plan is an explicit, serialisable list of timed fault events — crash or
restart a StateObject, restart a coordinator shard or the whole coordinator
service, partition/heal endpoint groups, and degrade links or whole
*message classes* (all ``report`` traffic, say) with loss / duplication /
delay. ``FaultPlan.random(seed, ...)`` derives an entire schedule from one
seed, so a failing run is reproducible from ``(scenario, seed)`` alone, and
``sim/explore.py`` shrinks a failing plan to a minimal repro by deleting
events and re-running.

Plans always end with a *healing epilogue* (heal + clear link overrides) so
every scenario's settle phase sees a clean fabric — liveness assertions
then check convergence, not luck.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

#: faults that lose volatile state (trigger rollback recovery)
STATE_LOSING = ("crash",)
#: faults that only degrade the fabric / control plane
BENIGN = (
    "partition",
    "heal",
    "link",
    "method_link",
    "clear_method_link",
    "restart_shard",
    "restart_coordinator",
    "checkpoint",
)

_METHOD_CLASSES = ("report", "poll", "receive_fragments", "increment", "put", "get")


@dataclass
class FaultEvent:
    at: float  # virtual seconds from scenario start
    kind: str
    arg: Dict[str, object] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"at": self.at, "kind": self.kind, "arg": self.arg}

    @staticmethod
    def from_json(obj: dict) -> "FaultEvent":
        return FaultEvent(at=float(obj["at"]), kind=str(obj["kind"]), arg=dict(obj.get("arg", {})))

    def __repr__(self) -> str:
        return f"@{self.at:.3f}s {self.kind}({self.arg})"


@dataclass
class FaultPlan:
    events: List[FaultEvent] = field(default_factory=list)

    # -- builder DSL ------------------------------------------------------- #
    def crash(self, at: float, so_id: str, restart: bool = True) -> "FaultPlan":
        self.events.append(FaultEvent(at, "crash", {"so_id": so_id, "restart": restart}))
        return self

    def restart_shard(self, at: float, idx: int) -> "FaultPlan":
        self.events.append(FaultEvent(at, "restart_shard", {"idx": idx}))
        return self

    def restart_coordinator(self, at: float) -> "FaultPlan":
        self.events.append(FaultEvent(at, "restart_coordinator", {}))
        return self

    def checkpoint(self, at: float) -> "FaultPlan":
        """Snapshot-compact the coordinator's durable store (DESIGN.md §11)
        — not a fault, but scheduled like one so compaction lands at
        adversarial moments relative to crashes and restarts. A no-op on
        clusters built with compaction disabled (the snapshot-vs-replay
        differential runs the same plan on both)."""
        self.events.append(FaultEvent(at, "checkpoint", {}))
        return self

    def partition(self, at: float, *groups: Sequence[str]) -> "FaultPlan":
        self.events.append(
            FaultEvent(at, "partition", {"groups": [sorted(g) for g in groups]})
        )
        return self

    def heal(self, at: float) -> "FaultPlan":
        self.events.append(FaultEvent(at, "heal", {}))
        return self

    def link(self, at: float, src: str, dst: str, **spec) -> "FaultPlan":
        self.events.append(FaultEvent(at, "link", {"src": src, "dst": dst, "spec": spec}))
        return self

    def method_link(self, at: float, method: str, **spec) -> "FaultPlan":
        self.events.append(
            FaultEvent(at, "method_link", {"method": method, "spec": spec})
        )
        return self

    def clear_method_link(self, at: float, method: str) -> "FaultPlan":
        self.events.append(FaultEvent(at, "clear_method_link", {"method": method}))
        return self

    # -- introspection ------------------------------------------------------ #
    def sorted_events(self) -> List[FaultEvent]:
        return sorted(self.events, key=lambda e: (e.at, e.kind))

    def loses_state(self) -> bool:
        return any(e.kind in STATE_LOSING for e in self.events)

    # -- serialisation (explore.py artifacts, scenario files) --------------- #
    def to_json(self) -> list:
        return [e.to_json() for e in self.sorted_events()]

    @staticmethod
    def from_json(obj: list) -> "FaultPlan":
        return FaultPlan([FaultEvent.from_json(e) for e in obj])

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=2)

    @staticmethod
    def loads(text: str) -> "FaultPlan":
        return FaultPlan.from_json(json.loads(text))

    def without(self, indices: Sequence[int]) -> "FaultPlan":
        """A copy with the events at ``indices`` (into sorted_events) removed
        — the shrinking primitive."""
        drop = set(indices)
        return FaultPlan(
            [e for i, e in enumerate(self.sorted_events()) if i not in drop]
        )

    # -- generation --------------------------------------------------------- #
    @staticmethod
    def random(
        seed: int,
        *,
        so_ids: Sequence[str],
        horizon: float,
        n_shards: int = 0,
        endpoints: Optional[Sequence[str]] = None,
        allow_crash: bool = False,
        allow_coordinator_restart: bool = True,
        max_events: int = 6,
        max_loss: float = 0.3,
    ) -> "FaultPlan":
        """Derive a whole schedule from one seed. By default only *benign*
        faults (nothing that loses application state) so linearizability
        holds unconditionally; ``allow_crash=True`` adds crash-restarts for
        scenarios that assert the recovery invariants instead."""
        rng = random.Random(seed)
        plan = FaultPlan()
        kinds: List[str] = ["link", "method_link", "partition"]
        if n_shards:
            kinds.append("restart_shard")
        elif allow_coordinator_restart:
            kinds.append("restart_coordinator")
        if allow_crash:
            kinds += ["crash", "crash"]  # weight crashes up when allowed
        eps = list(endpoints or [f"so/{s}" for s in so_ids])
        coord_eps = (
            [f"coord/{i}" for i in range(n_shards)] if n_shards else ["coord"]
        )
        n = rng.randint(1, max_events)
        for _ in range(n):
            at = rng.uniform(0.05, horizon * 0.8)
            kind = rng.choice(kinds)
            if kind == "crash":
                plan.crash(at, rng.choice(list(so_ids)))
            elif kind == "restart_shard":
                plan.restart_shard(at, rng.randrange(n_shards))
            elif kind == "restart_coordinator":
                plan.restart_coordinator(at)
            elif kind == "partition":
                # cut either the coordinator or one service endpoint off,
                # then heal within the horizon
                victim = (
                    set(coord_eps) if rng.random() < 0.5 else {rng.choice(eps)}
                )
                plan.partition(at, victim)
                plan.heal(min(horizon, at + rng.uniform(0.05, horizon * 0.25)))
            elif kind == "link":
                src = rng.choice(["*"] + eps)
                dst = rng.choice(eps + coord_eps)
                plan.link(
                    at,
                    src,
                    dst,
                    latency_ms=rng.uniform(0, 2.0),
                    jitter_ms=rng.uniform(0, 1.0),
                    loss_prob=rng.uniform(0, max_loss),
                    dup_prob=rng.uniform(0, 0.3),
                    reorder_prob=rng.uniform(0, 0.3),
                )
                plan.link(min(horizon, at + rng.uniform(0.1, horizon * 0.4)), src, dst)
            else:  # method_link
                m = rng.choice(_METHOD_CLASSES)
                plan.method_link(
                    at,
                    m,
                    latency_ms=rng.uniform(0, 2.0),
                    loss_prob=rng.uniform(0, max_loss),
                    dup_prob=rng.uniform(0, 0.4),
                )
                plan.clear_method_link(
                    min(horizon, at + rng.uniform(0.1, horizon * 0.4)), m
                )
        # healing epilogue: the settle phase always sees a clean fabric
        plan.heal(horizon)
        for m in _METHOD_CLASSES:
            plan.clear_method_link(horizon, m)
        return plan
