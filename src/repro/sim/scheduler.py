"""Deterministic simulation runtime (DESIGN.md §8): virtual time + a
seeded single-runner cooperative scheduler.

FoundationDB-style: every thread of control in the system under test is a
*simulation task*; exactly one task executes at any moment, and a task
relinquishes control only at a blocking primitive (sleep, event/condition
wait, contended lock). The scheduler then picks the next runnable task with
a **seeded RNG** and, when nothing is runnable, jumps virtual time straight
to the earliest deadline — a 60-virtual-second partition test runs in
milliseconds of wall time.

Tasks are real OS threads for implementation convenience (the DSE stack is
written in blocking style), but the strict one-at-a-time hand-off makes
execution deterministic: same seed + same scenario => byte-identical event
trace (asserted in ``tests/test_sim.py``). Determinism covers scheduling,
virtual time, and every fault roll; it does NOT cover content that hashes
differently across *processes* (``PYTHONHASHSEED``) or JAX kernel numerics
— see DESIGN.md §8 for the contract.

The :class:`SimClock` it exposes implements :class:`repro.core.clock.Clock`,
so the entire stack (transport, runtime, coordinator, services) runs under
simulation unmodified — production code paths keep the real clock.
"""
from __future__ import annotations

import itertools
import random
import sys
import threading
import traceback
from typing import Any, Callable, Dict, List, Optional

from ..core.clock import Clock, SpawnHandle

_RUNNABLE = "runnable"
_RUNNING = "running"
_BLOCKED = "blocked"
_DONE = "done"


class TaskCancelled(BaseException):
    """Raised inside a task when the simulation tears down. BaseException so
    ordinary ``except Exception`` service code does not swallow it."""


class SimDeadlock(RuntimeError):
    """Every task is blocked and no deadline exists to advance time to."""


class SimTimeout(RuntimeError):
    """Virtual time (or the event budget) exceeded the scenario limit."""


class SimTaskError(RuntimeError):
    """A non-root task died with an unhandled exception."""


class _Task(SpawnHandle):
    def __init__(self, sched: "SimScheduler", tid: int, name: str, fn: Callable[[], Any]) -> None:
        self._sched = sched
        self.tid = tid
        self.name = name
        self.fn = fn
        self.sem = threading.Semaphore(0)
        self.thread: Optional[threading.Thread] = None
        self.state = _RUNNABLE
        self.wake_at: Optional[float] = None  # virtual deadline (sleep/timed wait)
        self.cancelled = False
        self.error: Optional[BaseException] = None
        self.result: Any = None
        self.done = SimEvent(sched)

    # -- SpawnHandle ----------------------------------------------------- #
    def join(self, timeout: Optional[float] = None) -> None:
        if self.state == _DONE:
            return
        self.done.wait(timeout)

    def is_alive(self) -> bool:
        return self.state != _DONE

    # -- thread body ------------------------------------------------------ #
    def _bootstrap(self) -> None:
        try:
            self.result = self.fn()
        except TaskCancelled:
            pass
        except BaseException as e:  # noqa: BLE001 — recorded, surfaced by run()
            self.error = e
        finally:
            self.state = _DONE
            self.done.set()
            self._sched._trace_event("done", self)
            self._sched._sched_sem.release()


class SimEvent:
    """Cooperative ``threading.Event`` equivalent bound to a scheduler."""

    def __init__(self, sched: "SimScheduler") -> None:
        self._sched = sched
        self._flag = False
        self._waiters: List[_Task] = []

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        self._flag = True
        if self._waiters:
            waiters, self._waiters = self._waiters, []
            for t in waiters:
                self._sched._wake(t)

    def clear(self) -> None:
        self._flag = False

    def wait(self, timeout: Optional[float] = None) -> bool:
        if self._flag:
            return True
        if timeout is not None and timeout <= 0:
            return self._flag
        sched = self._sched
        me = sched._require_task()
        self._waiters.append(me)
        sched._yield_current(None if timeout is None else sched.now + timeout)
        if me in self._waiters:  # woke by timeout, not set()
            self._waiters.remove(me)
        return self._flag


class SimLock:
    """Cooperative non-reentrant lock. A paused task may hold it; waiters
    yield to the scheduler instead of blocking their OS thread, which is
    what keeps the single-runner scheduler deadlock-free."""

    def __init__(self, sched: "SimScheduler") -> None:
        self._sched = sched
        self._owner: Optional[_Task] = None
        self._waiters: List[_Task] = []

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        sched = self._sched
        me = sched._require_task()
        if self._owner is me:
            raise RuntimeError("SimLock is not reentrant (use clock.rlock())")
        if self._owner is None:
            self._owner = me
            return True
        if not blocking:
            return False
        deadline = None if timeout is None or timeout < 0 else sched.now + timeout
        while self._owner is not None:
            if deadline is not None and sched.now >= deadline:
                return False
            self._waiters.append(me)
            sched._yield_current(deadline)
            if me in self._waiters:
                self._waiters.remove(me)
        self._owner = me
        return True

    def release(self) -> None:
        self._owner = None
        for t in self._waiters:
            self._sched._wake(t)

    def locked(self) -> bool:
        return self._owner is not None

    def __enter__(self) -> "SimLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SimRLock:
    """Cooperative reentrant lock with the Condition save/restore hooks."""

    def __init__(self, sched: "SimScheduler") -> None:
        self._sched = sched
        self._owner: Optional[_Task] = None
        self._count = 0
        self._waiters: List[_Task] = []

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        sched = self._sched
        me = sched._require_task()
        if self._owner is me:
            self._count += 1
            return True
        if not blocking and self._owner is not None:
            return False
        deadline = None if timeout is None or timeout < 0 else sched.now + timeout
        while self._owner is not None:
            if not blocking:
                return False
            if deadline is not None and sched.now >= deadline:
                return False
            self._waiters.append(me)
            sched._yield_current(deadline)
            if me in self._waiters:
                self._waiters.remove(me)
        self._owner = me
        self._count = 1
        return True

    def release(self) -> None:
        if self._owner is not self._sched._current:
            raise RuntimeError("cannot release un-owned SimRLock")
        self._count -= 1
        if self._count == 0:
            self._owner = None
            for t in self._waiters:
                self._sched._wake(t)

    # threading.Condition protocol for reentrant locks
    def _release_save(self):
        count, self._count = self._count, 0
        self._owner = None
        for t in self._waiters:
            self._sched._wake(t)
        return count

    def _acquire_restore(self, count) -> None:
        self.acquire()
        self._count = count

    def _is_owned(self) -> bool:
        return self._owner is self._sched._current

    def __enter__(self) -> "SimRLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SimCondition:
    """Cooperative ``threading.Condition`` over a Sim(R)Lock."""

    def __init__(self, sched: "SimScheduler", lock=None) -> None:
        self._sched = sched
        self._lock = lock if lock is not None else SimRLock(sched)
        self._waiters: List[_Task] = []
        self.acquire = self._lock.acquire
        self.release = self._lock.release

    def __enter__(self):
        self._lock.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self._lock.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        sched = self._sched
        me = sched._require_task()
        if hasattr(self._lock, "_release_save"):
            saved = self._lock._release_save()
        else:
            self._lock.release()
            saved = None
        self._waiters.append(me)
        sched._yield_current(None if timeout is None else sched.now + timeout)
        timed_out = me in self._waiters
        if timed_out:
            self._waiters.remove(me)
        if saved is not None:
            self._lock._acquire_restore(saved)
        else:
            self._lock.acquire()
        return not timed_out

    def wait_for(self, predicate, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else self._sched.now + timeout
        result = predicate()
        while not result:
            remaining = None
            if deadline is not None:
                remaining = deadline - self._sched.now
                if remaining <= 0:
                    break
            self.wait(remaining)
            result = predicate()
        return result

    def notify(self, n: int = 1) -> None:
        woken, self._waiters = self._waiters[:n], self._waiters[n:]
        for t in woken:
            self._sched._wake(t)

    def notify_all(self) -> None:
        self.notify(len(self._waiters))


class SimClock(Clock):
    """The :class:`~repro.core.clock.Clock` a scheduler injects everywhere."""

    def __init__(self, sched: "SimScheduler") -> None:
        self._sched = sched

    def now(self) -> float:
        return self._sched.now

    def sleep(self, seconds: float) -> None:
        sched = self._sched
        sched._require_task()
        sched._yield_current(sched.now + max(float(seconds), 0.0))

    def event(self) -> SimEvent:
        return SimEvent(self._sched)

    def condition(self, lock=None) -> SimCondition:
        return SimCondition(self._sched, lock)

    def lock(self) -> SimLock:
        return SimLock(self._sched)

    def rlock(self) -> SimRLock:
        return SimRLock(self._sched)

    def spawn(self, fn: Callable[[], None], *, name: Optional[str] = None) -> _Task:
        return self._sched.spawn(fn, name=name)


class SimScheduler:
    """Seeded single-runner scheduler over virtual time (module docstring)."""

    def __init__(self, seed: int = 0, *, max_events: int = 5_000_000) -> None:
        self.seed = seed
        self._rng = random.Random(seed)
        self.now = 0.0
        self.clock = SimClock(self)
        self._tasks: List[_Task] = []
        self._tid = itertools.count(1)
        self._sched_sem = threading.Semaphore(0)
        self._current: Optional[_Task] = None
        self._trace: List[str] = []
        self.events = 0
        self._max_events = max_events
        self.task_failures: List[BaseException] = []

    # -- task registration ------------------------------------------------ #
    def spawn(self, fn: Callable[[], Any], *, name: Optional[str] = None) -> _Task:
        tid = next(self._tid)
        task = _Task(self, tid, name or f"task-{tid}", fn)
        self._tasks.append(task)
        self._trace_event("spawn", task)
        return task

    # -- primitives called from task threads ------------------------------ #
    def _require_task(self) -> _Task:
        t = self._current
        if t is None or t.thread is not threading.current_thread():
            raise RuntimeError(
                "simulation primitive used outside a simulation task — "
                "spawn the caller via clock.spawn()/SimScheduler.run()"
            )
        return t

    def _yield_current(self, wake_at: Optional[float]) -> None:
        """Block the calling task until the scheduler resumes it (at
        ``wake_at`` virtual time, or earlier via :meth:`_wake`)."""
        task = self._require_task()
        task.state = _BLOCKED
        task.wake_at = wake_at
        self._sched_sem.release()
        task.sem.acquire()
        if task.cancelled:
            raise TaskCancelled()

    def _wake(self, task: _Task) -> None:
        if task.state == _BLOCKED:
            task.state = _RUNNABLE
            task.wake_at = None

    # -- scheduling loop --------------------------------------------------- #
    def _trace_event(self, kind: str, task: _Task) -> None:
        self._trace.append(f"{self.events} t={self.now:.6f} {kind} {task.name}")

    def _run_task(self, task: _Task) -> None:
        self.events += 1
        self._trace_event("run", task)
        task.state = _RUNNING
        task.wake_at = None
        self._current = task
        if task.thread is None:
            task.thread = threading.Thread(
                target=task._bootstrap, name=f"sim:{task.name}", daemon=True
            )
            task.thread.start()
        else:
            task.sem.release()
        self._sched_sem.acquire()  # until the task yields or finishes
        self._current = None
        if task.error is not None and task.error not in self.task_failures:
            self.task_failures.append(task.error)

    def _step(self, max_virtual_time: float, advance_time: bool = True) -> bool:
        """One scheduling decision. Returns False when nothing can run."""
        runnable = [t for t in self._tasks if t.state == _RUNNABLE]
        if not runnable:
            if not advance_time:
                return False
            sleepers = [t for t in self._tasks if t.state == _BLOCKED and t.wake_at is not None]
            if not sleepers:
                return False
            target = min(t.wake_at for t in sleepers)
            if target > max_virtual_time:
                raise SimTimeout(
                    f"virtual time would pass {max_virtual_time}s "
                    f"(next deadline {target:.3f}s); blocked: "
                    + ", ".join(t.name for t in self._tasks if t.state == _BLOCKED)
                )
            self.now = max(self.now, target)
            for t in sleepers:
                if t.wake_at <= self.now:
                    t.state = _RUNNABLE
                    t.wake_at = None
            return True
        if self.events >= self._max_events:
            raise SimTimeout(
                f"event budget {self._max_events} exhausted at t={self.now:.6f} "
                f"(livelock? tasks spinning without advancing virtual time)\n"
                + self._task_stacks()
            )
        runnable.sort(key=lambda t: t.tid)
        pick = runnable[self._rng.randrange(len(runnable))]
        self._run_task(pick)
        return True

    def run(
        self,
        main_fn: Callable[[], Any],
        *,
        name: str = "main",
        max_virtual_time: float = 600.0,
        raise_task_failures: bool = True,
    ) -> Any:
        """Run ``main_fn`` as the root task until it completes; then drain
        already-runnable housekeeping tasks (no further time advance) and
        cancel the rest. Returns the root task's return value."""
        root = self.spawn(main_fn, name=name)
        try:
            while root.state != _DONE:
                if not self._step(max_virtual_time):
                    blocked = [t.name for t in self._tasks if t.state == _BLOCKED]
                    raise SimDeadlock(
                        f"all tasks blocked with no pending deadline; blocked: {blocked}"
                    )
            drain_budget = 10_000
            while drain_budget and self._step(max_virtual_time, advance_time=False):
                drain_budget -= 1
        finally:
            self._cancel_all()
        if root.error is not None:
            raise root.error
        failures = [e for e in self.task_failures if e is not root.error]
        if failures and raise_task_failures:
            raise SimTaskError(
                f"{len(failures)} background task(s) died: {failures[:3]!r}"
            ) from failures[0]
        return root.result

    def _cancel_all(self) -> None:
        for _ in range(100_000):
            alive = [t for t in self._tasks if t.state != _DONE and t.thread is not None]
            if not alive:
                break
            task = alive[0]
            task.cancelled = True
            task.state = _RUNNING
            self._current = task
            task.sem.release()
            self._sched_sem.acquire()
            self._current = None
        for t in self._tasks:
            if t.thread is None:  # spawned but never scheduled
                t.state = _DONE

    def _task_stacks(self, limit: int = 6) -> str:
        """Python stacks of every live task (diagnostics for timeouts)."""
        frames = sys._current_frames()
        out: List[str] = []
        for t in self._tasks:
            if t.state == _DONE or t.thread is None or t.thread.ident not in frames:
                continue
            stack = traceback.extract_stack(frames[t.thread.ident])
            app = [f for f in stack if "sim/scheduler.py" not in f.filename][-limit:]
            out.append(
                f"  task {t.name} [{t.state}]: "
                + " <- ".join(f"{f.name}@{f.filename.rsplit('/', 1)[-1]}:{f.lineno}" for f in reversed(app))
            )
        return "\n".join(out)

    # -- introspection ------------------------------------------------------ #
    def trace_text(self) -> str:
        return "\n".join(self._trace)

    def stats(self) -> Dict[str, float]:
        return {
            "events": self.events,
            "virtual_time": self.now,
            "tasks": len(self._tasks),
        }
