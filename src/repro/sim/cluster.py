"""SimCluster — run any existing DSE service unmodified under deterministic
simulation (DESIGN.md §8).

Wraps a :class:`~repro.net.cluster.NetCluster` whose transport, runtimes,
coordinator shards, and background threads all draw their time and blocking
primitives from one :class:`~repro.sim.scheduler.SimScheduler`. A scenario
is a plain function ``scenario(sim) -> value`` executed as the root
simulation task; a :class:`~repro.sim.faults.FaultPlan` is installed as a
parallel driver task; the run returns a :class:`SimResult` carrying the
value, the byte-exact event trace, recorded operation history, and
watermark samples for the invariant checkers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

from ..core.runtime import CrashedError
from ..net import LinkSpec, NetCluster, SimTransport
from .faults import FaultEvent, FaultPlan
from .invariants import Op, PENDING, WatermarkMonitor
from .scheduler import SimScheduler


@dataclass
class SimResult:
    value: Any
    trace: str
    events: int
    virtual_time: float
    transport_stats: Dict[str, float] = field(default_factory=dict)
    history: List[Op] = field(default_factory=list)
    watermarks: Optional[WatermarkMonitor] = None


class SimCluster:
    """One deterministic run of a (possibly sharded) DSE cluster.

    Everything — cluster construction included — happens inside the
    simulation, because ``Connect`` synchronously persists version 0 and
    that wait must be virtual. Use::

        sim = SimCluster(tmp_path, seed=7, n_shards=2)
        result = sim.run(scenario, plan=FaultPlan.random(7, ...))
    """

    def __init__(
        self,
        root: Path,
        *,
        seed: int = 0,
        n_shards: int = 0,
        default_link: Optional[LinkSpec] = None,
        refresh_interval: Optional[float] = 0.005,
        group_commit_interval: float = 0.010,
        retry_timeout: float = 0.01,
        call_timeout: float = 30.0,
        max_virtual_time: float = 600.0,
        runtime: str = "dse",
        **cluster_kw,
    ) -> None:
        #: ``runtime`` picks the execution engine every service Connects
        #: with — "dse" (speculative) or "durable" (synchronous baseline,
        #: repro.durable). The differential oracle (sim/differential.py)
        #: replays one scenario under both and diffs committed results.
        self.root = Path(root)
        self.seed = seed
        self.n_shards = n_shards
        self.scheduler = SimScheduler(seed=seed)
        self.clock = self.scheduler.clock
        self.max_virtual_time = max_virtual_time
        self._transport_kw = dict(
            # independent stream, deterministically derived from the seed
            seed=(seed * 2654435761 + 97) % (2**31),
            default_link=default_link,
            retry_timeout=retry_timeout,
            call_timeout=call_timeout,
        )
        self.runtime = runtime
        self._cluster_kw = dict(
            refresh_interval=refresh_interval,
            group_commit_interval=group_commit_interval,
            runtime=runtime,
            **cluster_kw,
        )
        self.transport: Optional[SimTransport] = None
        self.cluster: Optional[NetCluster] = None
        self.history: List[Op] = []
        self.watermarks = WatermarkMonitor()
        self._monitoring = False

    # ------------------------------------------------------------------ #
    # run harness                                                        #
    # ------------------------------------------------------------------ #
    def run(
        self,
        scenario: Callable[["SimCluster"], Any],
        *,
        plan: Optional[FaultPlan] = None,
        monitor_interval: Optional[float] = 0.02,
    ) -> SimResult:
        box: Dict[str, Any] = {}

        def main() -> None:
            self.transport = SimTransport(clock=self.clock, **self._transport_kw)
            self.cluster = NetCluster(
                self.root / "cluster",
                transport=self.transport,
                n_shards=self.n_shards,
                clock=self.clock,
                **self._cluster_kw,
            )
            if plan is not None:
                self._install_plan(plan)
            if monitor_interval is not None:
                self._start_monitor(monitor_interval)
            try:
                box["value"] = scenario(self)
            finally:
                self._monitoring = False
                try:
                    self.cluster.shutdown()
                except Exception:
                    pass  # teardown under residual faults must not mask results

        self.scheduler.run(main, max_virtual_time=self.max_virtual_time)
        return SimResult(
            value=box.get("value"),
            trace=self.scheduler.trace_text(),
            events=self.scheduler.events,
            virtual_time=self.scheduler.now,
            transport_stats=self.transport.stats() if self.transport else {},
            history=list(self.history),
            watermarks=self.watermarks,
        )

    # ------------------------------------------------------------------ #
    # fault plan driving                                                 #
    # ------------------------------------------------------------------ #
    def _install_plan(self, plan: FaultPlan) -> None:
        events = plan.sorted_events()

        def driver() -> None:
            t0 = self.clock.now()
            for ev in events:
                self.clock.sleep(max(0.0, t0 + ev.at - self.clock.now()))
                try:
                    self.apply_fault(ev)
                except KeyError:
                    pass  # fault targeted a service the scenario never added

        self.clock.spawn(driver, name="fault-driver")

    def apply_fault(self, ev: FaultEvent) -> None:
        assert self.cluster is not None and self.transport is not None
        arg = ev.arg
        if ev.kind == "crash":
            self.cluster.kill(str(arg["so_id"]), restart=bool(arg.get("restart", True)))
        elif ev.kind == "restart_shard":
            if self.n_shards:
                self.cluster.restart_shard(int(arg["idx"]) % self.n_shards)
        elif ev.kind == "restart_coordinator":
            self.cluster.restart_coordinator()
        elif ev.kind == "checkpoint":
            # a no-op on clusters built with compaction disabled — the
            # store owns that contract (CompactingLog.checkpoint), so the
            # snapshot-vs-replay differential can replay one plan on both
            # configurations without per-site guards.
            self.cluster.checkpoint()
        elif ev.kind == "partition":
            self.transport.partition(*[set(g) for g in arg["groups"]])
        elif ev.kind == "heal":
            self.transport.heal()
        elif ev.kind == "link":
            self.transport.set_link(str(arg["src"]), str(arg["dst"]), **dict(arg.get("spec", {})))
        elif ev.kind == "method_link":
            self.transport.set_method_link(str(arg["method"]), **dict(arg.get("spec", {})))
        elif ev.kind == "clear_method_link":
            self.transport.clear_method_link(str(arg["method"]))
        else:
            raise ValueError(f"unknown fault kind {ev.kind!r}")

    # ------------------------------------------------------------------ #
    # watermark monitor                                                  #
    # ------------------------------------------------------------------ #
    def _start_monitor(self, interval: float) -> None:
        self._monitoring = True

        def monitor() -> None:
            while self._monitoring:
                try:
                    boundary = self.cluster.coordinator.current_boundary()
                    fsn = self._fsn()
                    self.watermarks.sample(self.clock.now(), fsn, boundary)
                except Exception:
                    pass  # coordinator mid-restart: skip the sample
                self.clock.sleep(interval)

        self.clock.spawn(monitor, name="watermark-monitor")

    def _fsn(self) -> int:
        coord = self.cluster.coordinator
        if self.n_shards:
            return coord.bus.fsn()
        return int(coord.stats()["fsn"])

    # ------------------------------------------------------------------ #
    # scenario-side conveniences (delegate to the wrapped cluster)       #
    # ------------------------------------------------------------------ #
    def add(self, so_id: str, factory, **overrides):
        return self.cluster.add(so_id, factory, **overrides)

    def get(self, so_id: str):
        return self.cluster.get(so_id)

    def send(self, src_id, dst_id, method, *args, **kwargs):
        return self.cluster.send(src_id, dst_id, method, *args, **kwargs)

    def sleep(self, seconds: float) -> None:
        self.clock.sleep(seconds)

    def spawn(self, fn, *, name: Optional[str] = None):
        return self.clock.spawn(fn, name=name)

    def boundary(self) -> Optional[Dict[str, int]]:
        return self.cluster.coordinator.current_boundary()

    def settle(
        self,
        predicate: Callable[[], bool],
        *,
        timeout: float = 30.0,
        interval: float = 0.02,
    ) -> bool:
        """Deadline-poll ``predicate`` in virtual time, driving refresh
        rounds — the simulation twin of ``tests/conftest.settle``."""
        deadline = self.clock.now() + timeout
        while self.clock.now() < deadline:
            self.cluster.refresh_all()
            if predicate():
                return True
            self.clock.sleep(interval)
        return predicate()


class RecordingClient:
    """A client task identity that records every operation (invocation /
    response in virtual time) into ``sim.history`` for the linearizability
    checker, chaining DSE headers per client."""

    def __init__(self, sim: SimCluster, store_id: str, name: str) -> None:
        self.sim = sim
        self.store_id = store_id
        self.name = name
        self.header = None

    def _record(self, method: str, args: tuple, result, invoked, returned) -> None:
        self.sim.history.append(
            Op(self.name, method, args, result, invoked, returned)
        )

    def op(self, method: str, *args):
        """Issue ``method(*args, header)`` against the store; returns the
        service result, or None if the op is pending (timeout/crash)."""
        invoked = self.sim.clock.now()
        try:
            res = self.sim.send(self.name, self.store_id, method, *args, self.header)
        except (TimeoutError, CrashedError):
            # the request may or may not have been applied: pending forever
            self._record(method, args, PENDING, invoked, None)
            self.header = None
            return None
        returned = self.sim.clock.now()
        if res is None:
            # discarded (our header's deps were rolled back): no effect — do
            # not record; the client restarts its causal chain.
            self.header = None
            return None
        if method == "get":
            value, self.header = res
            self._record(method, args, value, invoked, returned)
            return value
        if method == "increment":
            value, self.header = res
            self._record(method, args, value, invoked, returned)
            return value
        # put / delete / stock: result is just the response header
        self.header = res
        self._record(method, args, "ok", invoked, returned)
        return res

    # sugar for the KV scenarios
    def put(self, key: str, value: str):
        return self.op("put", key, value)

    def get(self, key: str):
        return self.op("get", key)

    def delete(self, key: str):
        return self.op("delete", key)
