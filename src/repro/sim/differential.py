"""Differential oracle: one seeded op history + fault plan, replayed against
both runtimes — DurableRuntime (synchronous baseline) and DSERuntime
(speculative) — asserting op-for-op equivalence of committed results.

A runtime that persists synchronously before every externally-visible
effect is trivially correct (nothing speculative ever escapes), so the
durable run is the oracle: any divergence in *committed* observations is a
bug in speculation/rollback — the correctness argument Beldi (arXiv:
2010.06706) makes for its synchronous reference, applied to the whole DSE
stack under deterministic simulation.

What equivalence covers (and what it doesn't — DESIGN.md §10): committed
observations are compared — per-workflow recorded step results (exposed
only behind the final barrier) and the post-settle durable service state.
Transient speculative acks that the protocol later discards are *supposed*
to differ between runs and are not compared; timing, persists-per-op, and
wire traffic obviously differ (that gap is the paper's Figure 9, measured
by ``benchmarks/bench_eval.py``).

Workloads are workflow-shaped on purpose: a bare client's acked-but-
unbarriered suffix may legitimately vanish under DSE, so the driver records
its own progress in a StateObject that rolls back *with* its effects
(``WorkflowEngine``), exactly the durable-execution programming model both
runtimes claim to serve. Steps are idempotent (put/delete/get and
owner-keyed ``try_reserve``) — the standard activity contract that makes
retry-after-lost-reply single-effect in the durable baseline too.

Scenarios are registered first-class in ``repro.sim.explore``::

    python -m repro.sim.explore --scenario differential_kv --seeds 50
"""
from __future__ import annotations

import random
from pathlib import Path
from typing import Any, Dict, List, Optional

from ..core.runtime import CrashedError
from ..core.sthread import RolledBackError
from .cluster import SimCluster, SimResult
from .faults import FaultPlan
from .invariants import InvariantViolation, check_shard_logs

#: driver retry budget: a workflow is re-driven after every rollback /
#: crash / timeout until it completes (the fault plan's healing epilogue
#: guarantees eventual success on a correct stack).
MAX_DRIVES = 200


def default_differential_plan(seed: int, horizon: float = 0.8) -> FaultPlan:
    """Crash + partition schedule over both participants (the acceptance
    bar: zero divergences under crash+partition fault plans)."""
    return FaultPlan.random(
        seed,
        so_ids=["kv", "wf"],
        horizon=horizon,
        n_shards=2,
        allow_crash=True,
    )


# --------------------------------------------------------------------------- #
# workloads                                                                   #
# --------------------------------------------------------------------------- #
def _kv_scripts(
    seed: int, n_drivers: int = 2, n_workflows: int = 5, n_ops: int = 4
) -> List[List[dict]]:
    """Per-driver workflow scripts over DISJOINT key sets: each driver's get
    results are then a pure function of its own prior ops, so committed
    results must match across runtimes op-for-op regardless of cross-driver
    scheduling differences.

    Many SMALL workflows with pauses in between, not one big one: workflows
    then *complete* (expose results) continuously across the fault horizon,
    so crash faults land inside the window right after an exposure — the
    window where an exposure-before-durability bug (e.g. a broken barrier)
    is distinguishable from the durable oracle at all. One long workflow
    finishing before the first fault would leave speculation unobserved.
    """
    rng = random.Random(seed ^ 0xD1FFE12)
    scripts: List[List[dict]] = []
    for d in range(n_drivers):
        keys = [f"k{d}{j}" for j in range(3)]
        wfs = []
        for _ in range(n_workflows):
            ops = []
            for _ in range(n_ops):
                kind = rng.choice(["put", "put", "get", "delete"])
                ops.append((kind, rng.choice(keys), f"v{rng.randrange(30)}"))
            wfs.append({"ops": ops, "pause": rng.uniform(0.02, 0.1)})
        scripts.append(wfs)
    return scripts


def _run_side(
    workload: str,
    seed: int,
    root: Path,
    plan: FaultPlan,
    runtime: str,
    horizon: float = 0.8,
    sim_kw: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    from ..services.kv_store import SpeculativeKVStore
    from ..services.workflow import WorkflowEngine

    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        runtime=runtime,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
        **(sim_kw or {}),
    )
    scripts = _kv_scripts(seed) if workload == "kv" else None
    # workflow workload shape: several small staggered workflows (see
    # _kv_scripts docstring for why many-small beats one-big)
    n_workflows, n_steps = 6, 2

    def scenario(sim: SimCluster):
        sim.add("kv", lambda: SpeculativeKVStore(sim.root / "so_kv"))
        sim.add("wf", lambda: WorkflowEngine(sim.root / "so_wf"))
        obs: Dict[str, Any] = {"runtime": runtime, "outcomes": {}}

        if workload == "workflow":
            # seed inventory and make it durable before faults can bite:
            # the stock op itself is part of neither run's compared history
            for _ in range(MAX_DRIVES):
                try:
                    if sim.send(None, "kv", "stock", "seat", n_workflows * n_steps, None) is None:
                        continue
                    kv = sim.get("kv")
                    if kv.StartAction(None) and kv.wait_durable(timeout=10.0):
                        kv.EndAction()
                        break
                except (TimeoutError, CrashedError, RolledBackError):
                    pass
                sim.sleep(0.01)

        def kv_steps(d: int, w: int):
            steps = []
            for kind, key, value in scripts[d][w]["ops"]:
                if kind == "put":
                    args = ("put", key, value)
                elif kind == "delete":
                    args = ("delete", key)
                else:
                    args = ("get", key)

                def step(h, args=args):
                    out = sim.send("wf", "kv", *args, h)
                    if out is None:
                        return None
                    if args[0] == "get":
                        return out  # (value, header)
                    return ("ok", out)  # put/delete return just the header

                steps.append(step)
            return steps

        def reserve_steps(wf_id: str):
            return [
                (
                    lambda h, i=i: sim.send(
                        "wf", "kv", "try_reserve", "seat", f"{wf_id}:{i}", h
                    )
                )
                for i in range(n_steps)
            ]

        def drive(wf_id: str, steps_for) -> None:
            for _ in range(MAX_DRIVES):
                try:
                    # re-fetch each attempt: a crash fault replaces the engine
                    out = sim.get("wf").run_workflow(wf_id, steps_for())
                except (TimeoutError, CrashedError, RolledBackError):
                    out = None
                if out is not None:
                    obs["outcomes"][wf_id] = out[0]
                    return
                sim.sleep(0.02)
            obs["outcomes"][wf_id] = None  # liveness failure — flagged below

        if workload == "kv":

            def kv_driver(d: int) -> None:
                # sequential small workflows with pauses: exposures spread
                # across the whole fault horizon
                for w, wf in enumerate(scripts[d]):
                    drive(f"d{d}w{w}", lambda d=d, w=w: kv_steps(d, w))
                    sim.sleep(wf["pause"])

            tasks = [
                sim.spawn((lambda d=d: kv_driver(d)), name=f"diff-driver{d}")
                for d in range(len(scripts))
            ]
        else:

            def reserve_driver(i: int) -> None:
                sim.sleep(0.02 + i * 0.09)  # staggered completions
                drive(f"wf{i}", lambda i=i: reserve_steps(f"wf{i}"))

            tasks = [
                sim.spawn((lambda i=i: reserve_driver(i)), name=f"diff-driver{i}")
                for i in range(n_workflows)
            ]
        for t in tasks:
            t.join()

        # outlive the fault plan, then settle to a converged, served boundary
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)
        sim.settle(
            lambda: sim.boundary() is not None
            and sim.get("kv").runtime.world == sim.get("wf").runtime.world,
            timeout=30.0,
        )

        # committed final state (post-settle, clean fabric: plain reads)
        final: Dict[str, Optional[str]] = {}
        if workload == "kv":
            keys = sorted(
                {op[1] for script in scripts for wf in script for op in wf["ops"]}
            )
        else:
            keys = ["inv:seat"] + [
                f"res:seat:wf{i}:{s}" for i in range(n_workflows) for s in range(n_steps)
            ]
        for k in keys:
            out = sim.send(None, "kv", "get", k, None)
            final[k] = out[0] if out is not None else "<discarded>"
        obs["final"] = final
        obs["wf_state"] = {
            wf_id: (sim.get("wf").workflow_state(wf_id) or {}).get("status")
            for wf_id in obs["outcomes"]
        }
        # durable-store generations (vacuity witness for the snapshot
        # differential: the compact side must actually have compacted)
        stats = sim.cluster.coordinator.stats()
        obs["store_generations"] = sum(
            dict(stats.get("log_generations", {})).values()
        ) or int(stats.get("log_generation", 0))
        return obs

    result = sim.run(scenario, plan=plan)
    errors = list(result.watermarks.check()) if result.watermarks else []
    errors += check_shard_logs(root / "cluster" / "coord")
    if errors:
        raise InvariantViolation(f"[differential/{runtime} seed={seed}] " + " | ".join(errors))
    obs = result.value
    obs["_result"] = result
    return obs


# --------------------------------------------------------------------------- #
# the oracle: replay on both runtimes, diff committed observations            #
# --------------------------------------------------------------------------- #
def _diff_observations(
    oracle: Dict[str, Any], subject: Dict[str, Any], a: str, b: str
) -> List[str]:
    """Divergences between two sides' committed observations (workflow
    outcomes, final durable state, workflow statuses); ``a``/``b`` label the
    oracle and subject sides in the messages."""
    divergences: List[str] = []
    for wf_id in sorted(set(oracle["outcomes"]) | set(subject["outcomes"])):
        o, s = oracle["outcomes"].get(wf_id), subject["outcomes"].get(wf_id)
        if o is None or s is None:
            divergences.append(
                f"{wf_id} never completed ({a}={o is not None}, {b}={s is not None})"
            )
        elif o != s:
            divergences.append(f"{wf_id} committed results diverge: {a}={o} {b}={s}")
    if oracle["final"] != subject["final"]:
        diff = {
            k: (oracle["final"].get(k), subject["final"].get(k))
            for k in sorted(set(oracle["final"]) | set(subject["final"]))
            if oracle["final"].get(k) != subject["final"].get(k)
        }
        divergences.append(f"final committed state diverges ({a}, {b}): {diff}")
    if oracle["wf_state"] != subject["wf_state"]:
        divergences.append(
            f"workflow statuses diverge: {a}={oracle['wf_state']} {b}={subject['wf_state']}"
        )
    return divergences


def run_differential(
    workload: str, seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    if plan is None:
        plan = default_differential_plan(seed)
    sides = {
        rt: _run_side(workload, seed, Path(root) / rt, plan, rt)
        for rt in ("durable", "dse")
    }
    oracle, subject = sides["durable"], sides["dse"]
    divergences = _diff_observations(oracle, subject, "durable", "dse")
    if divergences:
        raise InvariantViolation(
            f"[differential_{workload} seed={seed}] DSE diverges from the durable "
            "oracle: " + " | ".join(divergences)
        )

    result: SimResult = subject.pop("_result")
    oracle.pop("_result", None)
    result.value = {"durable": oracle, "dse": subject}
    return result


def differential_kv_scenario(
    seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """Sequential put/get/delete scripts (disjoint keys per driver) through
    the workflow engine, on both runtimes, under crash+partition faults."""
    return run_differential("kv", seed, root, plan)


def differential_workflow_scenario(
    seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """The TravelReservations-style try_reserve workload on both runtimes:
    outcomes, inventory, and reservation markers must match exactly."""
    return run_differential("workflow", seed, root, plan)


# --------------------------------------------------------------------------- #
# snapshot-vs-replay: compaction must be observationally invisible            #
# --------------------------------------------------------------------------- #
def default_snapshot_plan(seed: int, horizon: float = 0.9) -> FaultPlan:
    """Long-horizon crash/restart schedule with compaction points pinned
    between them: every seed exercises checkpoint → shard restart →
    recovery-from-snapshot+suffix at least twice, on top of the random
    crash/partition schedule."""
    plan = FaultPlan.random(
        seed,
        so_ids=["kv", "wf"],
        horizon=horizon,
        n_shards=2,
        allow_crash=True,
    )
    for at in (0.12, 0.3, 0.48, 0.66):
        plan.checkpoint(at)
    plan.restart_shard(0.2, seed % 2)
    plan.restart_shard(0.55, (seed + 1) % 2)
    # full coordinator-service restarts: the DecisionBus survives single
    # shard restarts and would mask a broken snapshot (state re-seeded from
    # the bus); only a full restart rebuilds everything from the durable
    # stores — the path the snapshot actually carries.
    plan.restart_coordinator(0.4)
    plan.restart_coordinator(0.72)
    return plan


def run_store_differential(
    workload: str, seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """Replay one seeded history + fault plan on two identically-seeded DSE
    clusters: one with snapshot compaction armed (tight auto threshold +
    the plan's explicit checkpoint events, so shard restarts recover from
    snapshot + log suffix), one with compaction disabled (restarts replay
    the full log — the seed-era recovery path). Committed observations must
    match op-for-op: a compaction bug is precisely the kind of silent
    divergence this oracle exists to catch (DESIGN.md §11). Scheduling
    differs between the sides (checkpoints perturb the interleaving), which
    is exactly why the drivers' committed results are scheduling-invariant
    by construction — same argument as the runtime differential above."""
    if plan is None:
        plan = default_snapshot_plan(seed)
    sides = {
        mode: _run_side(workload, seed, Path(root) / mode, plan, "dse", sim_kw=kw)
        for mode, kw in (
            ("replay", {"checkpoint_records": None}),
            ("compact", {"checkpoint_records": 6}),
        )
    }
    oracle, subject = sides["replay"], sides["compact"]
    divergences = _diff_observations(oracle, subject, "replay", "compact")
    if divergences:
        raise InvariantViolation(
            f"[snapshot_recovery_{workload} seed={seed}] recovery from "
            "snapshot+suffix diverges from full replay: " + " | ".join(divergences)
        )
    if not subject.get("store_generations", 0):
        raise InvariantViolation(
            f"[snapshot_recovery_{workload} seed={seed}] the compact side "
            "never checkpointed — the differential ran vacuously"
        )

    result: SimResult = subject.pop("_result")
    oracle.pop("_result", None)
    result.value = {"replay": oracle, "compact": subject}
    return result


def snapshot_recovery_kv_scenario(
    seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """Disjoint-key workflow scripts over kv under crashes + shard restarts:
    committed results with compaction must equal the full-replay run's."""
    return run_store_differential("kv", seed, root, plan)


def snapshot_recovery_workflow_scenario(
    seed: int, root: Path, plan: Optional[FaultPlan] = None
) -> SimResult:
    """try_reserve workload: compaction must not change outcomes, inventory,
    or reservation markers relative to full-replay recovery."""
    return run_store_differential("workflow", seed, root, plan)
