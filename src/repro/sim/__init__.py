"""repro.sim — deterministic simulation for the whole DSE stack
(DESIGN.md §8).

FoundationDB-style: virtual time + a seeded cooperative scheduler
(:mod:`~repro.sim.scheduler`), seeded fault schedules
(:mod:`~repro.sim.faults`), machine-checked invariants including a
Wing–Gong linearizability checker (:mod:`~repro.sim.invariants`), a
:class:`~repro.sim.cluster.SimCluster` facade that runs any existing
service unmodified under simulation, and a seed-sweep driver with fault
plan shrinking (:mod:`~repro.sim.explore`).
"""
from .scheduler import (
    SimClock,
    SimDeadlock,
    SimScheduler,
    SimTaskError,
    SimTimeout,
    TaskCancelled,
)
from .faults import FaultEvent, FaultPlan
from .invariants import (
    CounterModel,
    InvariantViolation,
    KVModel,
    Op,
    PENDING,
    WatermarkMonitor,
    check_exactly_once_counter,
    check_linearizable,
    check_shard_logs,
)
from .cluster import RecordingClient, SimCluster, SimResult

#: explore is imported lazily: eager import here would make the documented
#: ``python -m repro.sim.explore`` CLI execute the module twice (runpy's
#: found-in-sys.modules RuntimeWarning, with duplicated module state).
_EXPLORE_EXPORTS = ("SCENARIOS", "default_plan", "run_one", "shrink", "sweep")


def __getattr__(name):
    if name in _EXPLORE_EXPORTS:
        from . import explore

        return getattr(explore, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "SimClock",
    "SimDeadlock",
    "SimScheduler",
    "SimTaskError",
    "SimTimeout",
    "TaskCancelled",
    "FaultEvent",
    "FaultPlan",
    "CounterModel",
    "InvariantViolation",
    "KVModel",
    "Op",
    "PENDING",
    "WatermarkMonitor",
    "check_exactly_once_counter",
    "check_linearizable",
    "check_shard_logs",
    "RecordingClient",
    "SimCluster",
    "SimResult",
    "SCENARIOS",
    "default_plan",
    "run_one",
    "shrink",
    "sweep",
]
