"""Speculative write-ahead log (paper §5.2, "Speculative Log").

Mirrors the paper's FasterLog wrapper: *commit records* (one per Persist)
carry the libDSE metadata and mark the durable frontier; recovering or
rolling back "simply drops all log entries after the latest surviving
commit record", driven by an in-memory commit map for speed (the paper's
multiversioning fast path).

On disk each commit is one *segment* file holding the entries appended
since the previous commit. Rolled-back versions can be re-persisted under
the same numeric label by a later incarnation; segments are therefore named
``seg_<world>_<version>`` and readers dedupe by version keeping the highest
world (new labels always start above the rollback target, so duplicates can
only involve rolled-back versions — see DESIGN.md §2).

Speculative pruning (the paper's Fig. 10 storage-bandwidth saving): a
consumer may ``mark_consumed`` a prefix *inside an action*, making the
producer's next Persist skip those entries' bytes ("holes"). Correctness is
automatic from the dependency graph: consuming the ack header inside an
action makes the skipping version depend on the consumer's vertex, so if
the consumption is ever lost, the hole-bearing version is rolled back with
it and the entries are regenerated upstream.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from ..core.ids import Header
from ..core.state_object import StateObject


class LogCore:
    """Embeddable speculative log (no DSE wiring) — the broker reuses this
    per (topic, partition); :class:`SpeculativeLog` wraps exactly one."""

    def __init__(self, root: Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()
        self._entries: List[Optional[bytes]] = []  # None = pruned hole
        self._flushed_upto = 0                     # entries covered by segments
        self._prune_upto = 0                       # speculative-prune watermark
        self._commits: Dict[int, int] = {}         # version -> commit offset
        self._poisoned = False
        self.bytes_written = 0                     # Fig. 10 accounting
        self.entries_skipped = 0

    # -- appends / reads ---------------------------------------------------
    def append(self, data: bytes) -> int:
        with self._lock:
            self._entries.append(data)
            return len(self._entries) - 1

    def read(self, offset: int) -> Optional[bytes]:
        with self._lock:
            return self._entries[offset]

    def scan(self, start: int, end: Optional[int] = None) -> List[Tuple[int, bytes]]:
        with self._lock:
            end = len(self._entries) if end is None else min(end, len(self._entries))
            return [
                (i, self._entries[i])
                for i in range(start, end)
                if self._entries[i] is not None
            ]

    def tail(self) -> int:
        with self._lock:
            return len(self._entries)

    def mark_consumed(self, upto: int) -> None:
        """Entries below ``upto`` need not reach storage (caller must record
        the dependency on the consumer by receiving its header in the same
        action that triggers this)."""
        with self._lock:
            self._prune_upto = max(self._prune_upto, upto)

    # -- persistence -------------------------------------------------------
    def poison(self) -> None:
        self._poisoned = True

    def drop_memory(self) -> None:
        with self._lock:
            self._entries = []
            self._commits = {}
            self._flushed_upto = 0
            self._prune_upto = 0

    def flush(self, world: int, version: int, metadata: bytes) -> Callable[[], None]:
        """Capture the commit snapshot; return the (synchronous) IO closure.

        Must be called with actions quiesced (the runtime's exclusive epoch);
        the returned closure may run on any thread.
        """
        with self._lock:
            commit_offset = len(self._entries)
            start = self._flushed_upto
            batch: List[Optional[bytes]] = []
            skipped = 0
            for i in range(start, commit_offset):
                e = self._entries[i]
                if e is not None and i < self._prune_upto:
                    # speculatively-pruned: write a hole, not the bytes
                    self._entries[i] = None
                    e = None
                if e is None:
                    skipped += 1
                batch.append(e)
            self._flushed_upto = commit_offset
            self._commits[version] = commit_offset
        rec = {
            "world": world,
            "version": version,
            "start": start,
            "count": len(batch),
            "meta": metadata.hex(),
            "entries": [None if e is None else e.hex() for e in batch],
        }
        self.entries_skipped += skipped

        def _io() -> None:
            if self._poisoned:
                raise RuntimeError("LogCore poisoned (incarnation crashed)")
            data = json.dumps(rec).encode()
            tmp = self.root / f".seg_{world:04d}_{version:010d}.tmp"
            final = self.root / f"seg_{world:04d}_{version:010d}.json"
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            if self._poisoned:  # never PUBLISH from a crashed incarnation
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise RuntimeError("LogCore poisoned (incarnation crashed)")
            os.replace(tmp, final)
            self.bytes_written += len(data)

        return _io

    # -- recovery ----------------------------------------------------------
    def _disk_segments(self) -> List[dict]:
        """All segments, deduped by version keeping the highest world."""
        best: Dict[int, dict] = {}
        for p in sorted(self.root.glob("seg_*.json")):
            try:
                rec = json.loads(p.read_text())
            except Exception:
                continue
            v = rec["version"]
            if v not in best or rec["world"] > best[v]["world"]:
                best[v] = rec
        return [best[v] for v in sorted(best)]

    def restore(self, version: int) -> bytes:
        """Roll back (fast path: in-memory truncate) or reload from disk."""
        with self._lock:
            if version in self._commits and self._commits[version] <= len(self._entries):
                # fast path: multiversioned in-memory rollback
                off = self._commits[version]
                self._entries = self._entries[:off]
                self._flushed_upto = min(self._flushed_upto, off)
                self._prune_upto = min(self._prune_upto, off)
                self._commits = {v: o for v, o in self._commits.items() if v <= version}
                meta = b""
                for rec in self._disk_segments():
                    if rec["version"] == version:
                        meta = bytes.fromhex(rec["meta"])
                return meta
            # crash path: rebuild the entry list from the segment chain
            entries: List[Optional[bytes]] = []
            commits: Dict[int, int] = {}
            meta = b""
            for rec in self._disk_segments():
                if rec["version"] > version:
                    break
                assert rec["start"] == len(entries), "segment chain mismatch"
                entries.extend(
                    None if e is None else bytes.fromhex(e) for e in rec["entries"]
                )
                commits[rec["version"]] = len(entries)
                if rec["version"] == version:
                    meta = bytes.fromhex(rec["meta"])
            self._entries = entries
            self._flushed_upto = len(entries)
            self._prune_upto = 0
            self._commits = commits
            return meta

    def _floor(self) -> int:
        try:
            return int((self.root / "floor").read_text())
        except (FileNotFoundError, ValueError):
            return -1

    def list_versions(self) -> List[Tuple[int, bytes]]:
        """Commit records at or above the durable floor's anchor — the
        greatest persisted version <= the floor stays listable (the anchor
        contract of ``StateObject.Prune``), everything below it is pruned
        from the listing so reconnects/resends ship O(live), not the whole
        segment history (DESIGN.md §11)."""
        recs = self._disk_segments()
        floor = self._floor()
        anchor = max((r["version"] for r in recs if r["version"] <= floor), default=None)
        return [
            (rec["version"], bytes.fromhex(rec["meta"]))
            for rec in recs
            if anchor is None or rec["version"] >= anchor
        ]

    def prune(self, version: int) -> None:
        """Older *commit records* may be forgotten. Data segments are kept —
        they are the restore chain — but their commit entries drop from the
        in-memory map and from ListVersions via a floor marker."""
        floor = self.root / "floor"
        tmp = self.root / ".floor.tmp"
        tmp.write_text(str(version))
        os.replace(tmp, floor)


class SpeculativeLog(StateObject):
    """One LogCore exposed as a libDSE StateObject service."""

    def __init__(self, root: Path) -> None:
        super().__init__()
        self.core = LogCore(root)

    # -- persistence backend ------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        world = self.runtime.world if self.connected else 0
        io = self.core.flush(world, version, metadata)

        def _run() -> None:
            try:
                io()
            except RuntimeError:
                return
            callback()

        self.spawn_io(_run)

    def Restore(self, version: int) -> bytes:
        return self.core.restore(version)

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.core.list_versions()

    def Prune(self, version: int) -> None:
        self.core.prune(version)

    def on_crash(self) -> None:
        self.core.poison()
        self.core.drop_memory()

    # -- service API ---------------------------------------------------------
    def append(self, data: bytes, header: Optional[Header] = None):
        """Append one entry. Returns (offset, response_header) or None if the
        sender's state was rolled back (message must be discarded)."""
        if not self.StartAction(header):
            return None
        off = self.core.append(data)
        return off, self.EndAction()

    def read(self, offset: int, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        data = self.core.read(offset)
        return data, self.EndAction()

    def scan(self, start: int, end: Optional[int] = None, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        out = self.core.scan(start, end)
        return out, self.EndAction()

    def truncate_consumed(self, upto: int, header: Optional[Header] = None):
        """Consumer ack: entries below ``upto`` may skip storage. The ack
        header is consumed in this action so the dependency is recorded."""
        if not self.StartAction(header):
            return None
        self.core.mark_consumed(upto)
        return self.EndAction()
