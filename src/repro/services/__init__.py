"""Speculative cloud building blocks (paper §5.2): write-ahead log,
key-value store, workflow engine, event broker — plus the two-phase commit
application (paper §6.1) built from them."""
from .spec_log import LogCore, SpeculativeLog
from .kv_store import SpeculativeKVStore
from .workflow import WorkflowEngine
from .broker import EventBroker
from .two_phase_commit import TwoPCCoordinator, TwoPCParticipant, TwoPCClient

__all__ = [
    "LogCore",
    "SpeculativeLog",
    "SpeculativeKVStore",
    "WorkflowEngine",
    "EventBroker",
    "TwoPCCoordinator",
    "TwoPCParticipant",
    "TwoPCClient",
]
