"""The paper's running example (Figs. 1/3/4): an increment-counter service.
Used by tests, microbenchmarks, and the quickstart example."""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Callable, List, Optional, Tuple

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore


class CounterStateObject(StateObject):
    def __init__(self, root: Path, io_ms: float = 0.0) -> None:
        super().__init__()
        self.store = VersionStore(root, simulate_io_ms=io_ms)
        self.value = 0
        self._vlock = threading.Lock()

    # -- persistence backend (paper Table 1 / Fig. 3) ----------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        payload = self.value.to_bytes(8, "little", signed=True)

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return  # crashed incarnation never acks durability
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        self.value = int.from_bytes(payload, "little", signed=True)
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()

    # -- service API (paper Fig. 4) ------------------------------------------
    def increment(self, header: Optional[Header] = None, by: int = 1):
        """Returns (new_value, response_header), or None if the sender's
        state was rolled back (message discarded)."""
        if not self.StartAction(header):
            return None
        with self._vlock:
            self.value += by
            v = self.value
        return v, self.EndAction()
