"""Speculative workflow orchestration engine (paper §5.2, "Speculative
Workflows") — the core backend of a Temporal/Durable-Functions-style
engine, following the CReSt model: every workflow transition is an atomic
state change on speculatively-persisted state.

Control flow is part of persisted state (paper §4.1.1): the recorded step
index rolls back together with everything else, so after recovery the
workflow resumes "from exactly where it is supposed to" — re-invoking
``run_workflow`` with the same id continues from the surviving step index.

The current-generation durable-execution baseline (Temporal/Beldi/
Boki-style per-transition synchronous persistence, the paper's Figure-9
baseline) is no longer a bespoke flag here: deploy the engine with
``runtime="durable"`` (:class:`~repro.durable.DurableRuntime`) and every
``Detach``/``EndAction`` below becomes a synchronous durability wait — the
orchestration code is identical on both runtimes.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore
from ..core.sthread import RolledBackError

#: a workflow step: takes an outgoing header, performs the (remote) call,
#: returns (result, response_header) — or None if the callee discarded us.
Step = Callable[[Header], Optional[Tuple[object, Header]]]


class WorkflowEngine(StateObject):
    def __init__(self, root: Path, io_ms: float = 0.0) -> None:
        super().__init__()
        self.store = VersionStore(root, simulate_io_ms=io_ms)
        self._wfs: Dict[str, dict] = {}
        self._mu = threading.Lock()

    # -- persistence backend -------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        with self._mu:
            payload = json.dumps(self._wfs).encode()

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        with self._mu:
            self._wfs = json.loads(payload.decode())
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        with self._mu:
            self._wfs = {}

    # -- orchestration (paper Fig. 5) ------------------------------------------
    def run_workflow(
        self,
        wf_id: str,
        steps: List[Step],
        header: Optional[Header] = None,
        external: bool = True,
    ):
        """Execute (or resume) workflow ``wf_id``. Returns (results, header)
        once the outcome is safe to expose, or None if rolled back mid-way
        (the driver retries; surviving progress is preserved)."""
        if not self.StartAction(header):
            return None
        with self._mu:
            wf = self._wfs.setdefault(
                wf_id, {"status": "running", "step": 0, "results": []}
            )
            start_step = int(wf["step"])
        t = self.Detach()  # leave the atomic block: calls are long-running

        for i in range(start_step, len(steps)):
            try:
                out = steps[i](t.Send())
            except RolledBackError:
                return None
            if out is None:
                return None  # callee discarded our speculative message
            result, rh = out
            try:
                if not t.Receive(rh):
                    return None
            except RolledBackError:
                return None
            if not self.Merge(t):
                return None  # our own state rolled back; driver will resume
            with self._mu:
                wf = self._wfs[wf_id]
                wf["results"].append(result)
                wf["step"] = i + 1
            t = self.Detach()

        if not self.Merge(t):
            return None
        with self._mu:
            self._wfs[wf_id]["status"] = "done"
            results = list(self._wfs[wf_id]["results"])
        t = self.Detach()
        if external:
            # Failure transparency: only non-speculative results leave (§3.2).
            try:
                t.Barrier(timeout=30.0)
            except RolledBackError:
                return None
            if not self.Merge(t):
                return None
            return results, self.EndAction()
        # internal caller: pass speculation onward via the header
        h = t.Send()
        return results, h

    # -- recovery driver --------------------------------------------------------
    def pending_workflows(self) -> List[str]:
        """Workflows whose recorded status is not done (driver re-runs them
        after a rollback; recorded progress is the resume point)."""
        if not self.StartAction(None):
            return []
        with self._mu:
            out = [k for k, v in self._wfs.items() if v["status"] != "done"]
        self.EndAction()
        return out

    def workflow_state(self, wf_id: str) -> Optional[dict]:
        if not self.StartAction(None):
            return None
        with self._mu:
            st = self._wfs.get(wf_id)
            st = dict(st) if st is not None else None
        self.EndAction()
        return st
