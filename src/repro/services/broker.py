"""Speculative event broker (paper §5.2, "Event Broker") — Kafka/EventHubs
style topics over speculative logs, with DARQ-style exactly-once consumption
(consume → process → ack) and the Fig. 10 storage-bandwidth optimization:
events produced, consumed, and acked within a speculation window never
reach storage (their bytes are flushed as holes; the dependency recorded by
consuming the ack header makes this automatically safe — see spec_log.py).
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore
from .spec_log import LogCore


class EventBroker(StateObject):
    def __init__(self, root: Path, topics: List[str], partitions: int = 1) -> None:
        super().__init__()
        self.root = Path(root)
        self.partitions = partitions
        self._cores: Dict[Tuple[str, int], LogCore] = {
            (t, p): LogCore(self.root / t / f"p{p}")
            for t in topics
            for p in range(partitions)
        }
        # (group, topic, partition) -> next offset to consume
        self._offsets: Dict[str, int] = {}
        self._offsets_store = VersionStore(self.root / "_offsets")
        self._mu = threading.Lock()

    @staticmethod
    def _okey(group: str, topic: str, part: int) -> str:
        return f"{group}/{topic}/{part}"

    # -- persistence backend -------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        world = self.runtime.world if self.connected else 0
        ios = [core.flush(world, version, metadata) for core in self._cores.values()]
        with self._mu:
            offsets_payload = json.dumps(self._offsets).encode()

        def _run() -> None:
            try:
                for io in ios:
                    io()
                # offsets last: a version is listable only once every
                # partition segment for it is already durable.
                self._offsets_store.write(version, offsets_payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_run)

    def Restore(self, version: int) -> bytes:
        for core in self._cores.values():
            core.restore(version)
        payload, meta = self._offsets_store.read(version)
        with self._mu:
            self._offsets = json.loads(payload.decode())
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self._offsets_store.list_versions()

    def Prune(self, version: int) -> None:
        self._offsets_store.prune(version)
        for core in self._cores.values():
            core.prune(version)

    def on_crash(self) -> None:
        self._offsets_store.poison()
        self._offsets_store.drop_memory()
        for core in self._cores.values():
            core.poison()
            core.drop_memory()
        with self._mu:
            self._offsets = {}

    # -- service API ------------------------------------------------------------
    def produce(self, topic: str, events: List[bytes], header: Optional[Header] = None, part: int = 0):
        if not self.StartAction(header):
            return None
        core = self._cores[(topic, part)]
        offs = [core.append(e) for e in events]
        return offs, self.EndAction()

    def consume(self, group: str, topic: str, max_n: int = 64,
                header: Optional[Header] = None, part: int = 0):
        """Peek up to ``max_n`` events for ``group`` (offset advances at ack
        — DARQ-style exactly-once). Consuming REGISTERS the group: the
        speculative-prune floor only advances past offsets every registered
        group has acked, so a slow group never loses unacked events.
        Returns ([(offset, data)...], header)."""
        if not self.StartAction(header):
            return None
        core = self._cores[(topic, part)]
        with self._mu:
            key = self._okey(group, topic, part)
            start = self._offsets.setdefault(key, 0)
        events = core.scan(start, start + max_n)
        return events, self.EndAction()

    def ack(self, group: str, topic: str, upto: int,
            header: Optional[Header] = None, part: int = 0):
        """Advance ``group``'s offset past ``upto``. The consumer's header is
        consumed here, recording the dependency that makes speculative
        pruning of the acked prefix safe."""
        if not self.StartAction(header):
            return None
        key = self._okey(group, topic, part)
        core = self._cores[(topic, part)]
        with self._mu:
            self._offsets[key] = max(self._offsets.get(key, 0), upto + 1)
            # prune watermark = min over all groups consuming this partition
            floor = min(
                (
                    off
                    for k, off in self._offsets.items()
                    if k.split("/")[1] == topic and k.endswith(f"/{part}")
                ),
                default=0,
            )
        core.mark_consumed(floor)
        return self.EndAction()

    # -- accounting (Fig. 10) -----------------------------------------------------
    def storage_bytes_written(self) -> int:
        return sum(c.bytes_written for c in self._cores.values())

    def entries_skipped(self) -> int:
        return sum(c.entries_skipped for c in self._cores.values())

    def topic_tail(self, topic: str, part: int = 0) -> int:
        return self._cores[(topic, part)].tail()
