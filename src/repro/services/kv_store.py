"""Speculative key-value store (paper §5.2, FASTER-based in the original).

State is a hash map; ``Persist`` snapshots it into a multi-version store
(in-memory fast tier + durable blobs), mirroring FASTER's CPR-style
checkpointing at our abstraction level. Includes the stored procedures used
by the TravelReservations workload (paper §6.1): conditional reserve /
release over inventory counts.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore


class SpeculativeKVStore(StateObject):
    def __init__(self, root: Path, io_ms: float = 0.0) -> None:
        super().__init__()
        self.store = VersionStore(root, simulate_io_ms=io_ms)
        self._map: Dict[str, str] = {}
        self._mu = threading.Lock()

    # -- persistence backend -------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        with self._mu:
            payload = json.dumps(self._map).encode()

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        with self._mu:
            self._map = json.loads(payload.decode())
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        with self._mu:
            self._map = {}

    # -- service API -----------------------------------------------------------
    def get(self, key: str, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        with self._mu:
            val = self._map.get(key)
        return val, self.EndAction()

    def put(self, key: str, value: str, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        with self._mu:
            self._map[key] = value
        return self.EndAction()

    def delete(self, key: str, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        with self._mu:
            self._map.pop(key, None)
        return self.EndAction()

    # -- stored procedures (TravelReservations, paper §6.1) ---------------------
    def stock(self, item: str, count: int, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        with self._mu:
            self._map[f"inv:{item}"] = str(count)
        return self.EndAction()

    def try_reserve(self, item: str, owner: str, header: Optional[Header] = None):
        """Atomically decrement inventory; returns (ok, header) or None.

        Idempotent per (item, owner): a retried step whose first application
        survived (driver retry after a lost reply / workflow resume) must not
        double-decrement — the standard idempotency-key requirement of
        durable-execution activities (Temporal/Beldi), and what keeps the
        DSE-vs-durable differential oracle exact under crash faults.
        """
        if not self.StartAction(header):
            return None
        with self._mu:
            if self._map.get(f"res:{item}:{owner}") == "1":
                ok = True  # already applied: ack again without re-decrementing
            else:
                left = int(self._map.get(f"inv:{item}", "0"))
                ok = left > 0
                if ok:
                    self._map[f"inv:{item}"] = str(left - 1)
                    self._map[f"res:{item}:{owner}"] = "1"
        return ok, self.EndAction()

    def release(self, item: str, owner: str, header: Optional[Header] = None):
        """Saga compensation: undo a reservation."""
        if not self.StartAction(header):
            return None
        with self._mu:
            if self._map.pop(f"res:{item}:{owner}", None) is not None:
                self._map[f"inv:{item}"] = str(int(self._map.get(f"inv:{item}", "0")) + 1)
        return self.EndAction()
