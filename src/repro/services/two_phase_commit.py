"""Textbook two-phase commit (R* style, paper §2.2/§6.1) over speculative
logs. The protocol is UNCHANGED; only the persistence behaviour differs:

  * baseline (``speculative=False``): the coordinator logs start-of-commit
    before PREPARE, every participant logs its vote before replying, and the
    coordinator logs the decision before notifying — each a synchronous
    group-commit wait (this is why baseline commit latency clusters at
    multiples of the 10 ms group-commit period, paper Fig. 11);
  * speculative (``speculative=True``): identical log appends proceed
    without waiting; one speculation barrier before acknowledging the client
    hides all of it, so the persists of all parties overlap (latency ≈ max,
    not sum).
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional, Tuple

from ..core.ids import Header
from .spec_log import SpeculativeLog


class TwoPCParticipant(SpeculativeLog):
    def __init__(self, root: Path, speculative: bool = True) -> None:
        super().__init__(root)
        self.speculative = speculative
        self._txn_started: Dict[str, bool] = {}

    def on_crash(self) -> None:  # volatile index rebuilt lazily from log
        super().on_crash()
        self._txn_started = {}

    def _rebuild_index(self) -> None:
        self._txn_started = {}
        for _, e in self.core.scan(0):
            kind, txn = e.decode().split(":", 1)
            if kind == "start":
                self._txn_started[txn] = True

    def txn_start(self, txn: str, header: Optional[Header] = None):
        """Client writes a start record (without waiting for persistence —
        paper §6.1 benchmark definition)."""
        if not self.StartAction(header):
            return None
        self.core.append(f"start:{txn}".encode())
        self._txn_started[txn] = True
        return self.EndAction()

    def prepare(self, txn: str, header: Optional[Header] = None):
        """Vote yes iff the start record survives (it is lost only after a
        failure rolled it back). Baseline logs the vote durably first."""
        if not self.StartAction(header):
            return None
        if txn not in self._txn_started and self.core.tail() > 0:
            self._rebuild_index()
        vote = self._txn_started.get(txn, False)
        self.core.append(f"vote:{txn}:{'y' if vote else 'n'}".encode())
        if not self.speculative:
            if not self.wait_durable(timeout=30.0):
                return None
        return vote, self.EndAction()

    def decide(self, txn: str, commit: bool, header: Optional[Header] = None):
        if not self.StartAction(header):
            return None
        self.core.append(f"decide:{txn}:{'c' if commit else 'a'}".encode())
        return self.EndAction()


class TwoPCCoordinator(SpeculativeLog):
    def __init__(self, root: Path, speculative: bool = True) -> None:
        super().__init__(root)
        self.speculative = speculative

    def commit_txn(
        self,
        txn: str,
        participants: List[TwoPCParticipant],
        header: Optional[Header] = None,
    ) -> Optional[Tuple[bool, Header]]:
        """Run the commit protocol; returns (committed, hdr) once the outcome
        is externally safe, or None if this coordinator state rolled back."""
        if not self.StartAction(header):
            return None
        self.core.append(f"begin:{txn}".encode())
        if not self.speculative:
            if not self.wait_durable(timeout=30.0):
                return None
        t = self.Detach()

        # Phase 1: PREPARE
        votes: List[bool] = []
        for p in participants:
            out = p.prepare(txn, t.Send())
            if out is None:
                return None
            vote, rh = out
            if not t.Receive(rh):
                return None
            votes.append(vote)
        commit = all(votes)

        if not self.Merge(t):
            return None
        self.core.append(f"decision:{txn}:{'c' if commit else 'a'}".encode())
        if not self.speculative:
            if not self.wait_durable(timeout=30.0):
                return None
        t = self.Detach()

        # Phase 2: notify participants (need not block client ack)
        for p in participants:
            out = p.decide(txn, commit, t.Send())
            if out is None:
                return None
            if not t.Receive(out):
                return None

        if self.speculative:
            # single barrier replaces all synchronous waits above
            t.Barrier(timeout=30.0)
        if not self.Merge(t):
            return None
        return commit, self.EndAction()


class TwoPCClient:
    """Closed-loop transactional client (paper §6.1): writes a start record
    to every participant without waiting, then asks the coordinator to run
    commit."""

    def __init__(self, coordinator: TwoPCCoordinator, participants: List[TwoPCParticipant]):
        self.coordinator = coordinator
        self.participants = participants

    def run(self, txn: str) -> Optional[bool]:
        for p in self.participants:
            if p.txn_start(txn) is None:
                return None
        out = self.coordinator.commit_txn(txn, self.participants)
        if out is None:
            return None
        committed, _ = out
        return committed
