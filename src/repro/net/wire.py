"""Wire serialization for the transport fabric (DESIGN.md §9).

The fabric used to pickle every envelope payload wholesale; protocol
objects (headers on service calls, persist reports, rollback decisions,
poll responses) dominated that traffic and pickled as generic class dumps
— class path + attribute dict per object. This module keeps pickle as the
*container* (service args/kwargs are arbitrary user values) but routes
every DSE protocol type through the struct-packed binary codec in
:mod:`repro.core.ids` via a pickler dispatch table, so a protocol object
on the wire costs its varint-packed bytes plus a single reconstructor
reference.

The loader functions below are resolved by module path at unpickle time,
which doubles as the codec version gate: a blob produced by an older
(JSON) build decodes through the codec's legacy fallbacks.
"""
from __future__ import annotations

import io
import pickle
from typing import Any, Optional

from ..core import ids
from ..core.coordinator import PollResponse
from ..core.ids import Header, PersistReport, RollbackDecision


# -- reconstructors (must stay module-level: pickled by reference) ---------- #
def _load_header(raw: bytes) -> Header:
    return Header.decode(raw)


def _load_report(raw: bytes) -> PersistReport:
    return ids.decode_report(raw)


def _load_decision(raw: bytes) -> RollbackDecision:
    return ids.decode_decision(raw)


def _load_poll(
    decisions: bytes, boundary: Optional[bytes], resend: bool, seq: int
) -> PollResponse:
    return PollResponse(
        decisions=ids.decode_decisions(decisions),
        boundary=None if boundary is None else ids.decode_boundary(boundary),
        resend_fragments=resend,
        boundary_seq=seq,
    )


_DISPATCH = {
    Header: lambda h: (_load_header, (h.encode(),)),
    PersistReport: lambda r: (_load_report, (ids.encode_report(r),)),
    RollbackDecision: lambda d: (_load_decision, (ids.encode_decision(d),)),
    PollResponse: lambda p: (
        _load_poll,
        (
            ids.encode_decisions(p.decisions),
            None if p.boundary is None else ids.encode_boundary(p.boundary),
            p.resend_fragments,
            p.boundary_seq,
        ),
    ),
}


class _WirePickler(pickle.Pickler):
    dispatch_table = _DISPATCH


def dumps(obj: Any) -> bytes:
    buf = io.BytesIO()
    _WirePickler(buf, protocol=pickle.HIGHEST_PROTOCOL).dump(obj)
    return buf.getvalue()


def loads(raw: bytes) -> Any:
    return pickle.loads(raw)
