"""NetCluster — LocalCluster whose protocol traffic flows over a Transport.

Where :class:`~repro.core.cluster.LocalCluster` wires every StateObject and
the coordinator together with direct in-process calls, NetCluster routes
``call`` (service→service), ``report``, ``poll``, and fragment-resend
traffic through a :class:`~repro.net.transport.Transport` — by default a
:class:`~repro.net.transport.SimTransport`, so tests and benchmarks can
inject loss, latency, reordering, and partitions, and measure batched
delivery. ``Connect`` stays on the direct control plane: it is the rare
membership operation (the paper's Kubernetes-triggered path), not the hot
protocol loop, and in the real deployment it rides the orchestrator's
reliable channel.

With ``n_shards >= 1``, the coordinator is a
:class:`~repro.net.sharded.ShardedCoordinator`: each shard is a transport
endpoint (``coord/<i>``), and every StateObject's runtime talks to its home
shard through a :class:`RemoteCoordinator` proxy.

Runtime choice rides the same path as every other deployment knob: pass
``runtime="durable"`` (cluster-wide, via LocalCluster) or per-SO
``add(..., runtime="durable")`` and the member runs the synchronous
durable-execution baseline (:class:`~repro.durable.DurableRuntime`) over
exactly the same transport, proxies, and shard endpoints — its per-action
commit blocks on the report RPC through :class:`RemoteCoordinator`, so the
baseline pays real fabric round-trips where DSE pays none.
"""
from __future__ import annotations

from pathlib import Path
from typing import Callable, Optional

from ..core import ids
from ..core.clock import Clock, REAL_CLOCK
from ..core.cluster import LocalCluster
from ..core.coordinator import Coordinator
from ..core.state_object import StateObject
from .sharded import ShardedCoordinator
from .transport import SimTransport, Transport


class RemoteCoordinator:
    """Participant-side coordinator handle whose hot-path traffic (report /
    poll / fragment resend) crosses the cluster transport. Resolves the
    cluster's *current* coordinator dynamically, so coordinator restarts do
    not strand stale references."""

    def __init__(self, cluster: "NetCluster", so_id: str) -> None:
        self._cluster = cluster
        self.so_id = so_id

    def _src(self) -> str:
        return f"so/{self.so_id}"

    def connect(self, so_id: str, fragments):
        # control plane: direct (see module docstring)
        return self._cluster.coordinator.connect(so_id, fragments)

    def report(self, so_id: str, reports):
        # Batch-encoded with one shared so_id table (DESIGN.md §9) — a
        # fragment resend names each dep SO once, not once per vertex.
        # Returns the coordinator's rejected-vertex list (admission ack).
        return self._cluster.transport.call(
            self._src(),
            self._cluster.coordinator_endpoint(so_id),
            "report",
            so_id,
            ids.encode_reports(list(reports)),
        )

    def receive_fragments(self, so_id: str, fragments) -> None:
        self._cluster.transport.call(
            self._src(),
            self._cluster.coordinator_endpoint(so_id),
            "receive_fragments",
            so_id,
            ids.encode_reports(list(fragments)),
        )

    def poll(self, so_id: str, known_world: int, known_boundary_seq: int = -1):
        return self._cluster.transport.call(
            self._src(),
            self._cluster.coordinator_endpoint(so_id),
            "poll",
            so_id,
            known_world,
            known_boundary_seq,
        )


class NetCluster(LocalCluster):
    def __init__(
        self,
        root: Path,
        *,
        transport: Optional[Transport] = None,
        n_shards: int = 0,
        clock: Clock = REAL_CLOCK,
        **kw,
    ) -> None:
        self.transport = transport if transport is not None else SimTransport(clock=clock)
        self.n_shards = n_shards
        super().__init__(root, clock=clock, **kw)

    # ------------------------------------------------------------------ #
    # deployment hooks                                                   #
    # ------------------------------------------------------------------ #
    def _make_coordinator(self):
        if self.n_shards:
            coord = ShardedCoordinator(
                self.root / "coord",
                n_shards=self.n_shards,
                clock=self.clock,
                **self._store_kw,
            )
            for shard in coord.shards:
                self.transport.register(
                    f"coord/{shard.shard_id}", self._shard_handler(shard.shard_id)
                )
        else:
            coord = Coordinator(self.root / "coordinator.jsonl", **self._store_kw)
            self.transport.register("coord", self._coord_handler())
        return coord

    def _coordinator_handle(self, so_id: str) -> RemoteCoordinator:
        return RemoteCoordinator(self, so_id)

    def coordinator_endpoint(self, so_id: str) -> str:
        if self.n_shards:
            return f"coord/{self.coordinator.shard_index(so_id)}"
        return "coord"

    # Handlers resolve through ``self.coordinator`` on every message so a
    # restarted coordinator (fresh object, same endpoint) keeps working.
    @staticmethod
    def _decode_args(method: str, args: tuple) -> tuple:
        """Report/fragment traffic arrives batch-encoded (see
        RemoteCoordinator); decode back to PersistReport lists."""
        if (
            method in ("report", "receive_fragments")
            and len(args) == 2
            and isinstance(args[1], (bytes, bytearray))
        ):
            return (args[0], ids.decode_reports(bytes(args[1])))
        return args

    def _coord_handler(self) -> Callable:
        def handle(method: str, *args, **kwargs):
            args = self._decode_args(method, args)
            return getattr(self.coordinator, method)(*args, **kwargs)

        return handle

    def _shard_handler(self, idx: int) -> Callable:
        def handle(method: str, *args, **kwargs):
            args = self._decode_args(method, args)
            return getattr(self.coordinator.shards[idx], method)(*args, **kwargs)

        return handle

    # ------------------------------------------------------------------ #
    # membership + service traffic                                       #
    # ------------------------------------------------------------------ #
    def add(self, so_id: str, factory: Callable[[], StateObject], **overrides) -> StateObject:
        self.transport.register(f"so/{so_id}", self._so_handler(so_id))
        return super().add(so_id, factory, **overrides)

    def _so_handler(self, so_id: str) -> Callable:
        def handle(method: str, *args, **kwargs):
            return getattr(self.get(so_id), method)(*args, **kwargs)

        return handle

    def send(self, src_id: Optional[str], dst_id: str, method: str, *args, **kwargs):
        """Service→service RPC across the fabric (the paper's instrumented
        gRPC call): DSE Headers ride in ``args``, delay-epoch messages are
        retried by the transport, and lost messages are retried with
        receiver-side dedup (exactly-once processing)."""
        src = f"so/{src_id}" if src_id else "client"
        return self.transport.call(src, f"so/{dst_id}", method, *args, **kwargs)

    # ------------------------------------------------------------------ #
    # failure injection                                                  #
    # ------------------------------------------------------------------ #
    def restart_shard(self, idx: int) -> None:
        """Crash-restart a single coordinator shard (sharded mode only)."""
        self.coordinator.restart_shard(idx)

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def shutdown(self) -> None:
        super().shutdown()
        self.transport.close()
