"""Sharded DSE coordinator (DESIGN.md §7).

The paper's coordinator is a singleton (§4.3); at cluster scale it becomes
the bottleneck — every Refresh round of every StateObject lands on it.
Netherite's answer is partitioning with a cross-partition ordering layer;
we mirror that shape: StateObjects are consistent-hashed across N
:class:`CoordinatorShard`s, each a full Coordinator with **its own durable
log** holding its members' membership records, their graph fragments, and
every rollback decision (decisions are broadcast-replicated to every
shard's log before release). An in-memory :class:`DecisionBus` merges the
per-shard state into the single global view the :class:`~repro.core.runtime.DSERuntime`
already consumes:

* **fsn allocation** — globally ordered failure sequence numbers (the bus
  allocates; replay recovers the counter as ``max`` over shard logs);
* **rollback decisions** — computed on the merged graph (a decision may
  roll back SOs on every shard), durably appended to every live shard's
  log, then released;
* **recoverable boundary** — the fixpoint of per-shard boundaries under
  exchanged watermark estimates: each round, every shard recomputes its
  local boundary treating other shards' current estimates as the durable
  watermarks of external SOs, until nothing changes. The iteration is
  monotonically decreasing from per-shard committed watermarks, so it
  terminates, and it converges to exactly the single-coordinator boundary
  on the union graph (chaotic iteration of a monotone operator).

The bus itself holds no durable state — like the paper's coordinator, its
point of truth is the collective persisted state of the shards, and a full
coordinator-service restart rebuilds it from shard logs + participant
fragment resends.
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.clock import Clock, REAL_CLOCK
from ..core.coordinator import ConnectResponse, Coordinator, PollResponse
from ..core.graph import DependencyGraph
from ..core.ids import PersistReport, RollbackDecision


class HashRing:
    """Consistent-hash ring with virtual nodes. Uses md5, not ``hash()``:
    Python's string hash is per-process randomized and would re-shard every
    membership on every run."""

    def __init__(self, nodes: Sequence[object], vnodes: int = 64) -> None:
        if not nodes:
            raise ValueError("HashRing needs at least one node")
        self._ring = sorted(
            (self._h(f"{node}#{i}"), node) for node in nodes for i in range(vnodes)
        )
        self._keys = [h for h, _ in self._ring]

    @staticmethod
    def _h(s: str) -> int:
        return int.from_bytes(hashlib.md5(s.encode()).digest()[:8], "big")

    def lookup(self, key: str):
        i = bisect.bisect(self._keys, self._h(key)) % len(self._ring)
        return self._ring[i][1]


class CoordinatorShard(Coordinator):
    """One coordinator shard: a full Coordinator for its assigned SOs whose
    world/decision/boundary hooks defer to the shared DecisionBus."""

    def __init__(
        self,
        shard_id: int,
        log_path: Path,
        bus: "DecisionBus",
        recovery_timeout: float = 30.0,
        clock: Clock = REAL_CLOCK,
        *,
        checkpoint_records: Optional[int] = 256,
        checkpoint_bytes: int = 1 << 20,
    ) -> None:
        self.shard_id = shard_id
        self._bus = bus
        super().__init__(
            log_path,
            recovery_timeout,
            clock=clock,
            checkpoint_records=checkpoint_records,
            checkpoint_bytes=checkpoint_bytes,
        )
        bus.register_shard(self)

    # -- state the bus reads (never under this shard's lock from the bus
    #    side while a shard thread could hold it and call into the bus) --- #
    def replayed_decisions(self) -> List[RollbackDecision]:
        with self._lock:
            return list(self._decisions)

    def current_fsn(self) -> int:
        """The fsn counter this shard's durable store recovered — may exceed
        max(replayed decisions) when the snapshot retired the whole prefix."""
        with self._lock:
            return self._fsn

    def retired_upto(self) -> int:
        with self._lock:
            return self._retired_upto

    def graph_view(self) -> DependencyGraph:
        return self._graph  # DependencyGraph is internally locked

    def member_ids(self) -> List[str]:
        with self._lock:
            return list(self._members)

    def take_dirty(self) -> bool:
        with self._lock:
            d, self._dirty = self._dirty, False
            return d

    def local_boundary(self, external: Dict[str, int]) -> Dict[str, int]:
        return self._graph.recoverable_boundary(external=external)

    def watermarks(self) -> Dict[str, int]:
        return self._graph.committed_watermarks()

    def prune_to(self, boundary: Dict[str, int]) -> None:
        for so in self.member_ids():
            if so in boundary:
                self._graph.prune(so, boundary[so])

    def commit_decision(self, decision: RollbackDecision) -> None:
        """Broadcast arm: durably append a (possibly remote-origin) decision
        to this shard's log and apply its truncations to local members."""
        with self._lock:
            if decision.fsn <= self._retired_upto:
                # this shard's compactor already proved the decision can
                # never match anything again; re-appending it (a catch-up
                # from a slower shard's replay) would just regrow the log
                return
            i = bisect.bisect_left(self._decision_fsns, decision.fsn)
            if i < len(self._decision_fsns) and self._decision_fsns[i] == decision.fsn:
                return  # already committed to this shard's log
            self._log.append({"type": "decision", **decision.to_json()})
            self._note_decision(decision)
            for so, t in decision.targets.items():
                if so in self._members:
                    self._graph.truncate(so, t)
            self._dirty = True
        self._bus.mark_dirty()

    # -- snapshot + compaction (DESIGN.md §11) ----------------------------- #
    def checkpoint(self, floor: Optional[Dict[str, int]] = None) -> int:
        """Checkpoint this shard at ``floor`` — the cross-shard consistent
        cut (the bus's global exposure-floor estimate). Fetched WITHOUT the
        shard lock held when not supplied (the bus reaches across shards)."""
        if floor is None:
            floor = self._bus.global_boundary() or {}
        with self._lock:
            return self._checkpoint_locked(dict(floor))

    def maybe_checkpoint(self, floor: Dict[str, int]) -> None:
        """Auto-compaction arm, driven by the bus's boundary recompute (the
        base class's trigger rides ``_boundary_locked``, which sharded
        deployments never take — their floor lives on the bus)."""
        with self._lock:
            if self._log.should_checkpoint():
                self._checkpoint_locked(dict(floor))

    # -- merged-view hooks (called WITHOUT self._lock, see Coordinator) --- #
    def _world(self) -> int:
        return self._bus.fsn()

    def _all_decisions(self) -> List[RollbackDecision]:
        return self._bus.all_decisions()

    def _decide(self, so_id: str, surviving: int) -> RollbackDecision:
        return self._bus.decide(so_id, surviving)

    def _boundary_with_seq(self, known_seq=None):
        return self._bus.global_boundary_with_seq(known_seq)

    def _boundary(self) -> Optional[Dict[str, int]]:
        return self._bus.global_boundary()

    def poll(self, so_id: str, known_world: int, known_boundary_seq: int = -1) -> PollResponse:
        # Unlike the base class this cannot be one critical section: the
        # decision/boundary sources live on the DecisionBus and must be
        # reached WITHOUT this shard's lock held (cross-shard deadlock, see
        # the hook comment in Coordinator).
        with self._lock:
            resend = so_id in self._awaiting
        decisions = [d for d in self._all_decisions() if d.fsn > known_world]
        boundary, seq = self._boundary_with_seq(known_boundary_seq)
        return PollResponse(
            decisions=decisions,
            boundary=boundary,
            resend_fragments=resend,
            boundary_seq=seq,
        )

    def _ingest(self, reports) -> None:
        super()._ingest(reports)
        self._bus.mark_dirty()  # plain flag set: safe under self._lock


class DecisionBus:
    """Merges per-shard coordinator state into the single global view.

    Lock discipline (deadlock-freedom): ``_decide_lock`` and ``_boundary_mu``
    are only ever acquired by threads holding NO shard lock, and shard locks
    are acquired inside them one at a time. Shard threads holding their own
    lock only ever touch ``mark_dirty`` (plain attribute write) or
    ``_dlock``-guarded accessors, which never wait on shard locks.
    """

    def __init__(self, recovery_timeout: float = 30.0, clock: Clock = REAL_CLOCK) -> None:
        self._clock = clock
        self._dlock = threading.Lock()  # decisions dict + fsn + shard list
        # Held across waits / cross-shard lock acquisitions => must be
        # clock-sourced (a real lock held by a paused simulation task would
        # deadlock the cooperative scheduler, see core/clock.py).
        self._decide_lock = clock.lock()  # serializes rollback decisions
        self._boundary_mu = clock.lock()  # boundary cache
        self._shards: List[CoordinatorShard] = []
        self._decisions: Dict[int, RollbackDecision] = {}
        self._fsn = 0
        self._recovery_timeout = recovery_timeout
        self._dirty = True
        self._bcache: Dict[str, int] = {}
        #: generation of ``_bcache`` (guarded by _boundary_mu): lets shard
        #: polls answer "nothing moved" without shipping the boundary dict
        self._bseq = 0

    # -- membership ------------------------------------------------------- #
    def register_shard(self, shard: CoordinatorShard) -> None:
        # Serialize with decide(): a shard registering mid-broadcast would
        # replay its log from before the in-flight decision's append AND
        # miss it in the catch-up below (it enters self._decisions only
        # after the broadcast), silently losing the decision.
        with self._decide_lock:
            replayed = shard.replayed_decisions()
            # the shard's recovered counter can exceed its replayed decisions
            # when its snapshot retired the whole prefix (DESIGN.md §11)
            shard_fsn = shard.current_fsn()
            with self._dlock:
                self._shards = [s for s in self._shards if s.shard_id != shard.shard_id]
                self._shards.append(shard)
                self._shards.sort(key=lambda s: s.shard_id)
                for d in replayed:
                    self._decisions.setdefault(d.fsn, d)
                self._fsn = max(self._fsn, shard_fsn)
                if self._decisions:
                    self._fsn = max(self._fsn, max(self._decisions))
            # catch the shard up on decisions it missed while down (its log
            # was not part of the broadcast); commit_decision dedups by fsn.
            for d in self.all_decisions():
                shard.commit_decision(d)
            self._dirty = True

    def shards(self) -> List[CoordinatorShard]:
        with self._dlock:
            return list(self._shards)

    # -- global decision state -------------------------------------------- #
    def fsn(self) -> int:
        with self._dlock:
            return self._fsn

    def all_decisions(self) -> List[RollbackDecision]:
        with self._dlock:
            return sorted(self._decisions.values(), key=lambda d: d.fsn)

    def mark_dirty(self) -> None:
        self._dirty = True

    def decide(self, failed_so: str, surviving: int) -> RollbackDecision:
        """Global rollback decision: merged-graph fixpoint, broadcast-durable
        append to every shard's log, then release."""
        with self._decide_lock:
            self._wait_all_recovered()
            merged = DependencyGraph()
            for shard in self.shards():
                merged.merge_from(shard.graph_view())
            # pre-truncation tops: the retirement witness (see Coordinator._decide)
            tops = merged.committed_watermarks()
            merged.truncate(failed_so, surviving)
            targets = merged.rollback_targets(failed_so, surviving)
            with self._dlock:
                fsn = self._fsn + 1
                self._fsn = fsn
            decision = RollbackDecision(
                fsn=fsn,
                failed=failed_so,
                targets=targets,
                lost={so: tops.get(so, t) for so, t in targets.items()},
            )
            for shard in self.shards():
                shard.commit_decision(decision)
            with self._dlock:
                self._decisions[fsn] = decision
            self._dirty = True
            return decision

    def _wait_all_recovered(self) -> None:
        """A decision on an incomplete global view would erase innocent
        members of a recovering shard; wait for every shard's fragments."""
        deadline = self._clock.now() + self._recovery_timeout
        while any(s.is_awaiting for s in self.shards()):
            if self._clock.now() > deadline:
                stalled = [s.shard_id for s in self.shards() if s.is_awaiting]
                raise TimeoutError(
                    f"decision stalled; shards {stalled} still collecting fragments"
                )
            self._clock.sleep(0.002)

    # -- global boundary --------------------------------------------------- #
    def global_boundary_with_seq(
        self, known_seq: Optional[int] = None
    ) -> Tuple[Optional[Dict[str, int]], int]:
        shards = self.shards()
        if any(s.is_awaiting for s in shards):
            # some shard's view is incomplete: refuse, like §4.3
            with self._boundary_mu:
                return None, self._bseq
        with self._boundary_mu:
            dirty = self._dirty
            self._dirty = False
            for s in shards:
                dirty = s.take_dirty() or dirty
            if dirty:
                est: Dict[str, int] = {}
                for s in shards:
                    est.update(s.watermarks())
                changed = True
                while changed:
                    changed = False
                    for s in shards:
                        for so, w in s.local_boundary(est).items():
                            if w < est.get(so, -1):
                                est[so] = w
                                changed = True
                if est != self._bcache:
                    self._bcache = est
                    self._bseq += 1
                for s in shards:
                    s.prune_to(est)
                    # auto-compaction: same thread that prunes (holds no
                    # shard lock), same consistent cross-shard cut
                    s.maybe_checkpoint(est)
                # a decision every shard's compactor retired is globally
                # dead — drop it from the volatile union too, so Connect
                # responses ship O(retained) decisions
                retired = min((s.retired_upto() for s in shards), default=0)
                if retired:
                    with self._dlock:
                        for fsn in [f for f in self._decisions if f <= retired]:
                            del self._decisions[fsn]
            if known_seq == self._bseq:
                return None, self._bseq  # nothing moved: no dict shipped
            return dict(self._bcache), self._bseq

    def global_boundary(self) -> Optional[Dict[str, int]]:
        return self.global_boundary_with_seq()[0]


class ShardedCoordinator:
    """Drop-in replacement for :class:`~repro.core.coordinator.Coordinator`
    that consistent-hashes StateObjects across N shards. Implements the same
    participant API (connect / report / receive_fragments / poll), so
    ``DSERuntime`` is oblivious to the sharding."""

    def __init__(
        self,
        root: Path,
        n_shards: int = 2,
        *,
        recovery_timeout: float = 30.0,
        vnodes: int = 64,
        clock: Clock = REAL_CLOCK,
        checkpoint_records: Optional[int] = 256,
        checkpoint_bytes: int = 1 << 20,
    ) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n_shards = n_shards
        self._recovery_timeout = recovery_timeout
        self.clock = clock
        self._store_kw = dict(
            checkpoint_records=checkpoint_records, checkpoint_bytes=checkpoint_bytes
        )
        self.ring = HashRing(list(range(n_shards)), vnodes=vnodes)
        self.bus = DecisionBus(recovery_timeout, clock=clock)
        self.shards: List[CoordinatorShard] = [
            CoordinatorShard(
                i,
                self.root / f"shard{i}.jsonl",
                self.bus,
                recovery_timeout,
                clock=clock,
                **self._store_kw,
            )
            for i in range(n_shards)
        ]

    # -- placement -------------------------------------------------------- #
    def shard_index(self, so_id: str) -> int:
        return self.ring.lookup(so_id)

    def shard_for(self, so_id: str) -> CoordinatorShard:
        return self.shards[self.shard_index(so_id)]

    # -- participant API (Coordinator-compatible) -------------------------- #
    def connect(self, so_id: str, fragments: Sequence[PersistReport]) -> ConnectResponse:
        return self.shard_for(so_id).connect(so_id, fragments)

    def report(self, so_id: str, reports: Sequence[PersistReport]):
        # pass the admission ack (rejected-vertex list) through: a durable
        # runtime on this handle must not mistake "dropped" for "admitted"
        return self.shard_for(so_id).report(so_id, reports)

    def receive_fragments(self, so_id: str, fragments: Sequence[PersistReport]) -> None:
        self.shard_for(so_id).receive_fragments(so_id, fragments)

    def poll(self, so_id: str, known_world: int, known_boundary_seq: int = -1) -> PollResponse:
        return self.shard_for(so_id).poll(so_id, known_world, known_boundary_seq)

    # -- failure injection -------------------------------------------------- #
    def restart_shard(self, idx: int) -> CoordinatorShard:
        """Crash-restart one shard: the replacement replays the shard log and
        refuses to contribute to the global boundary until every one of its
        members has resent fragments (scale-out version of §4.3 recovery)."""
        old = self.shards[idx]
        # Build + register the replacement BEFORE closing the old shard: the
        # bus's shard list must never expose a closed log to a concurrent
        # decision broadcast (register_shard atomically swaps by shard_id).
        self.shards[idx] = CoordinatorShard(
            idx,
            self.root / f"shard{idx}.jsonl",
            self.bus,
            self._recovery_timeout,
            clock=self.clock,
            **self._store_kw,
        )
        old.close()
        return self.shards[idx]

    # -- snapshot + compaction (DESIGN.md §11) ------------------------------- #
    def checkpoint(self) -> List[int]:
        """Checkpoint every shard at one consistent cross-shard cut — the
        bus's current exposure-floor estimate (None while any shard is
        collecting fragments => an empty floor: still rotates, retires
        nothing). Returns the new generation per shard."""
        floor = self.bus.global_boundary() or {}
        return [s.checkpoint(floor) for s in self.shards]

    # -- introspection / lifecycle ------------------------------------------ #
    def current_boundary(self) -> Optional[Dict[str, int]]:
        return self.bus.global_boundary()

    def stats(self) -> Dict[str, object]:
        per_shard = {s.shard_id: s.stats() for s in self.shards}  # one lock trip each
        return {
            "members": sorted(m for st in per_shard.values() for m in st["members"]),
            "fsn": self.bus.fsn(),
            "decisions": len(self.bus.all_decisions()),
            "shards": self.n_shards,
            "per_shard_members": {sid: st["members"] for sid, st in per_shard.items()},
            "awaiting": sorted(
                so for st in per_shard.values() for so in st["awaiting"]
            ),
            "checkpoints": sum(s.checkpoints for s in self.shards),
            # durable store generations survive shard restarts (manifest),
            # unlike the per-object ``checkpoints`` counters
            "log_generations": {sid: st["log_generation"] for sid, st in per_shard.items()},
        }

    def close(self) -> None:
        for s in self.shards:
            s.close()
