"""Simulated transport fabric for cluster-scale DSE (DESIGN.md §7).

The paper's libDSE deployment runs StateObjects on real nodes over gRPC; the
seed repo wires everything with direct in-process calls. This module closes
the gap with an in-process *fabric*: endpoints exchange wire-encoded
envelopes (binary DSE protocol codec, ``net/wire.py``)
carrying DSE :class:`~repro.core.ids.Header` payloads, and every link can be
configured with latency, jitter, probabilistic loss, reordering, and
partitions. Delivery is *batched* per endpoint (Netherite-style: one worker
wakeup drains every due message), which is what makes the transport path
cheap at scale — see ``benchmarks/bench_net.py``.

Delivery semantics: at-least-once on the wire (senders retry on a per-attempt
timeout) + receiver-side dedup by message id => exactly-once *processing*.
A handler raising :class:`~repro.core.sthread.DelayMessage` (message from a
future failure epoch, paper Def 4.3) is answered with a ``delay`` status that
is deliberately NOT cached, so the sender's retry re-invokes the handler
after it has caught up — the transport equivalent of the retry loop in
``LocalCluster.call``.
"""
from __future__ import annotations

import copy
import heapq
import itertools
import random
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from ..core.clock import Clock, REAL_CLOCK
from ..core.sthread import DelayMessage
from . import wire

#: handler(method, *args, **kwargs) -> result
Handler = Callable[..., Any]


@dataclass
class LinkSpec:
    """Fault/latency model of one directed link (or the fabric default)."""

    latency_ms: float = 0.0
    jitter_ms: float = 0.0
    loss_prob: float = 0.0
    reorder_prob: float = 0.0
    reorder_ms: float = 1.0  # extra delay applied to reordered messages
    dup_prob: float = 0.0  # wire-level duplication (dedup makes processing 1x)
    dup_ms: float = 1.0  # extra delay applied to the duplicate copy


@dataclass
class Envelope:
    msg_id: str
    src: str
    dst: str
    method: str
    payload: bytes  # wire-encoded (args, kwargs) — measurable wire bytes
    attempt: int = 1
    deliver_at: float = 0.0
    needs_reply: bool = True  # False for cast(): no reply traffic, no dedup


class TransportError(Exception):
    pass


class Transport:
    """Abstract RPC fabric between named endpoints."""

    def register(self, endpoint_id: str, handler: Handler) -> None:
        raise NotImplementedError

    def call(self, src: str, dst: str, method: str, *args, timeout: Optional[float] = None, **kwargs):
        """Blocking RPC with the fabric's delivery semantics."""
        raise NotImplementedError

    def cast(self, src: str, dst: str, method: str, *args, **kwargs) -> None:
        """Fire-and-forget send (no reply, no retry)."""
        raise NotImplementedError

    def stats(self) -> Dict[str, float]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class DirectTransport(Transport):
    """Baseline: direct in-process dispatch (what the seed repo does), with
    the same retry-on-delay semantics so callers are transport-agnostic."""

    def __init__(
        self,
        *,
        call_timeout: float = 0.4,
        delay_backoff: float = 0.002,
        clock: Clock = REAL_CLOCK,
    ) -> None:
        self._eps: Dict[str, Handler] = {}
        self._call_timeout = call_timeout
        self._delay_backoff = delay_backoff
        self._clock = clock
        self._calls = 0

    def register(self, endpoint_id: str, handler: Handler) -> None:
        self._eps[endpoint_id] = handler

    def call(self, src: str, dst: str, method: str, *args, timeout: Optional[float] = None, **kwargs):
        handler = self._eps[dst]
        self._calls += 1
        deadline = self._clock.now() + (timeout if timeout is not None else self._call_timeout)
        while True:
            try:
                return handler(method, *args, **kwargs)
            except DelayMessage:
                if self._clock.now() >= deadline:
                    raise TimeoutError(f"{src}->{dst} {method}: delayed past retry budget")
                self._clock.sleep(self._delay_backoff)

    def cast(self, src: str, dst: str, method: str, *args, **kwargs) -> None:
        self._calls += 1
        try:
            self._eps[dst](method, *args, **kwargs)
        except Exception:
            pass  # fire-and-forget parity with SimTransport.cast

    def stats(self) -> Dict[str, float]:
        return {"calls": self._calls}


class _Waiter:
    """Reply slot for one in-flight RPC. Retries mean several replies for the
    same msg_id can race ``resolve``; the lock makes take-then-clear atomic so
    the caller can never observe a set event with an empty result."""

    __slots__ = ("_mu", "event", "_result")

    def __init__(self, clock: Clock) -> None:
        self._mu = threading.Lock()
        self.event = clock.event()
        self._result: Optional[Tuple[str, bytes]] = None

    def resolve(self, status: str, blob: bytes) -> None:
        with self._mu:
            self._result = (status, blob)
            self.event.set()

    def take(self) -> Optional[Tuple[str, bytes]]:
        with self._mu:
            result, self._result = self._result, None
            self.event.clear()
            return result


class _TimedQueue:
    """Min-heap of (deliver_at, item) drained by a dedicated thread: one
    wakeup pops every due item (up to ``max_batch``) and hands the batch to
    ``drain``. Shared by endpoint inboxes and the reply scheduler."""

    def __init__(
        self,
        name: str,
        drain: Callable[[List[Any]], None],
        max_batch: Optional[Callable[[], int]] = None,
        clock: Clock = REAL_CLOCK,
    ) -> None:
        self._clock = clock
        self._cv = clock.condition()
        self._heap: List[Tuple[float, int, Any]] = []
        self._seq = itertools.count()
        self._stop = False
        self._drain = drain
        self._max_batch = max_batch
        self._worker = clock.spawn(self._run, name=name)

    def push(self, deliver_at: float, item: Any) -> None:
        with self._cv:
            heapq.heappush(self._heap, (deliver_at, next(self._seq), item))
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify()

    def _run(self) -> None:
        while True:
            batch: List[Any] = []
            with self._cv:
                while not self._stop:
                    now = self._clock.now()
                    if self._heap and self._heap[0][0] <= now:
                        break
                    wait = (self._heap[0][0] - now) if self._heap else None
                    self._cv.wait(timeout=wait)
                if self._stop:
                    return
                now = self._clock.now()
                limit = self._max_batch() if self._max_batch else None
                while (
                    self._heap
                    and self._heap[0][0] <= now
                    and (limit is None or len(batch) < limit)
                ):
                    batch.append(heapq.heappop(self._heap)[2])
            self._drain(batch)


class _Endpoint:
    """One registered endpoint: a priority inbox drained in batches by a
    dedicated worker thread (per-endpoint FIFO up to injected reorder)."""

    def __init__(self, endpoint_id: str, handler: Handler, transport: "SimTransport") -> None:
        self.id = endpoint_id
        self.handler = handler
        self._t = transport
        # msg_id -> cached reply (exactly-once processing under retries)
        self._seen: "OrderedDict[str, Tuple[str, bytes]]" = OrderedDict()
        self._q = _TimedQueue(
            f"sim-ep-{endpoint_id}",
            self._drain_batch,
            max_batch=lambda: transport.batch_size,
            clock=transport.clock,
        )

    def push(self, env: Envelope) -> None:
        self._q.push(env.deliver_at, env)

    def stop(self) -> None:
        self._q.stop()

    def _drain_batch(self, batch: List[Envelope]) -> None:
        self._t._note_batch(len(batch))
        for env in batch:
            self._process(env)

    def _process(self, env: Envelope) -> None:
        if not env.needs_reply:
            # fire-and-forget: no reply traffic, no dedup (nothing retries),
            # and handler errors vanish with the message — a dying worker
            # thread is the one failure mode this must never have. Exception,
            # not BaseException: the simulation's TaskCancelled must fly.
            try:
                args, kwargs = wire.loads(env.payload)
                self.handler(env.method, *args, **kwargs)
            except Exception:  # noqa: BLE001
                pass
            return
        cached = self._seen.get(env.msg_id)
        if cached is not None:
            # duplicate of an already-processed request (its reply was lost):
            # resend the cached reply without re-invoking the handler.
            self._t._send_reply(env, *cached)
            return
        try:
            args, kwargs = wire.loads(env.payload)
            result = self.handler(env.method, *args, **kwargs)
            outcome = ("ok", wire.dumps(result))
        except DelayMessage:
            # deliberately uncached: the sender retries the SAME msg_id once
            # the receiver has caught up with the failure epoch.
            self._t._send_reply(env, "delay", b"")
            return
        except Exception as e:  # noqa: BLE001 — carried to the caller; the
            # simulation's TaskCancelled (a BaseException) must NOT be caught,
            # cached, and replied — it tears down this worker, nothing else
            try:
                blob = wire.dumps(e)
            except Exception:
                # unpicklable exception (locks, handles, device buffers):
                # degrade to a picklable stand-in rather than killing the
                # endpoint worker thread.
                blob = wire.dumps(RuntimeError(f"{type(e).__name__}: {e!r}"))
            outcome = ("err", blob)
        self._seen[env.msg_id] = outcome
        while len(self._seen) > self._t.dedup_cache_size:
            self._seen.popitem(last=False)
        self._t._send_reply(env, *outcome)


class SimTransport(Transport):
    """In-process fabric with per-link faults and batched delivery."""

    def __init__(
        self,
        *,
        seed: int = 0,
        default_link: Optional[LinkSpec] = None,
        batch_size: int = 64,
        call_timeout: float = 10.0,
        retry_timeout: float = 0.05,
        delay_backoff: float = 0.002,
        dedup_cache_size: int = 8192,
        clock: Clock = REAL_CLOCK,
    ) -> None:
        self.clock = clock
        self._rng = random.Random(seed)
        self._rng_mu = threading.Lock()
        self._eps: Dict[str, _Endpoint] = {}
        self._links: Dict[Tuple[str, str], LinkSpec] = {}
        self._method_links: Dict[str, LinkSpec] = {}
        self._default = default_link or LinkSpec()
        self._partition_groups: List[Set[str]] = []
        self._waiters: Dict[str, _Waiter] = {}
        self._waiters_mu = threading.Lock()
        self._msg_seq = itertools.count()
        self.batch_size = batch_size
        self.call_timeout = call_timeout
        self.retry_timeout = retry_timeout
        self.delay_backoff = delay_backoff
        self.dedup_cache_size = dedup_cache_size
        self._closed = False

        self._stats_mu = threading.Lock()
        self._stats = {
            "sent": 0,
            "delivered_batches": 0,
            "delivered_msgs": 0,
            "dropped_loss": 0,
            "dropped_partition": 0,
            "duplicated": 0,
            "retries": 0,
            "bytes": 0,
        }

        # reply scheduler: replies traverse the same faulty links
        self._replies = _TimedQueue("sim-replies", self._drain_replies, clock=clock)

    # -- topology -------------------------------------------------------- #
    def register(self, endpoint_id: str, handler: Handler) -> None:
        old = self._eps.get(endpoint_id)
        if old is not None:
            old.handler = handler  # re-register (restarted incarnation)
            return
        self._eps[endpoint_id] = _Endpoint(endpoint_id, handler, self)

    def set_link(self, src: str, dst: str, **spec) -> None:
        """Configure the directed link src->dst; ``"*"`` wildcards match any
        endpoint. Lookup precedence: method class (see
        :meth:`set_method_link`), (src,dst), (src,*), (*,dst), default."""
        self._links[(src, dst)] = LinkSpec(**spec)

    def set_method_link(self, method: str, **spec) -> None:
        """Fault a *message class*: every message carrying ``method``
        (e.g. all ``report`` or ``poll`` traffic), whatever its endpoints,
        takes this link spec. Fault plans use this to target protocol roles
        rather than topology."""
        self._method_links[method] = LinkSpec(**spec)

    def clear_method_link(self, method: str) -> None:
        self._method_links.pop(method, None)

    def _link(self, src: str, dst: str, method: Optional[str] = None) -> LinkSpec:
        if method is not None and method in self._method_links:
            return self._method_links[method]
        for key in ((src, dst), (src, "*"), ("*", dst)):
            if key in self._links:
                return self._links[key]
        return self._default

    def partition(self, *groups: Set[str]) -> None:
        """Split the fabric: endpoints communicate only within their group.
        Endpoints not listed in any group form one implicit remainder group.
        Messages crossing the cut are dropped (senders keep retrying, so a
        later :meth:`heal` lets the traffic through)."""
        self._partition_groups = [set(g) for g in groups]

    def heal(self) -> None:
        self._partition_groups = []

    def _cut(self, src: str, dst: str) -> bool:
        groups = self._partition_groups
        if not groups:
            return False

        def group_of(x: str) -> int:
            for i, g in enumerate(groups):
                if x in g:
                    return i
            return -1  # implicit remainder group

        return group_of(src) != group_of(dst)

    # -- send path ------------------------------------------------------- #
    def _roll(self, link: LinkSpec) -> Optional[Tuple[float, Optional[float]]]:
        """Returns (delay, duplicate_delay) in seconds — duplicate_delay is
        None unless the wire duplicated the message — or None if lost."""
        with self._rng_mu:
            if link.loss_prob and self._rng.random() < link.loss_prob:
                return None
            d = link.latency_ms
            if link.jitter_ms:
                d += self._rng.random() * link.jitter_ms
            if link.reorder_prob and self._rng.random() < link.reorder_prob:
                d += link.reorder_ms
            dup = None
            if link.dup_prob and self._rng.random() < link.dup_prob:
                dup = (d + link.dup_ms) / 1e3
        return d / 1e3, dup

    def _send(self, env: Envelope) -> None:
        with self._stats_mu:
            self._stats["sent"] += 1
            self._stats["bytes"] += len(env.payload)
        if self._cut(env.src, env.dst):
            with self._stats_mu:
                self._stats["dropped_partition"] += 1
            return
        rolled = self._roll(self._link(env.src, env.dst, env.method))
        if rolled is None:
            with self._stats_mu:
                self._stats["dropped_loss"] += 1
            return
        delay, dup = rolled
        ep = self._eps.get(env.dst)
        if ep is None:
            raise TransportError(f"unknown endpoint {env.dst!r}")
        env.deliver_at = self.clock.now() + delay
        ep.push(env)
        if dup is not None:
            # wire-level duplicate: same msg_id, so receiver-side dedup keeps
            # processing exactly-once (casts, which skip dedup, may observe it)
            with self._stats_mu:
                self._stats["duplicated"] += 1
            twin = copy.copy(env)
            twin.deliver_at = self.clock.now() + dup
            ep.push(twin)

    def _send_reply(self, request: Envelope, status: str, blob: bytes) -> None:
        """Schedule a reply over the dst->src link (same fault model)."""
        with self._stats_mu:
            self._stats["bytes"] += len(blob)
        if self._cut(request.dst, request.src):
            with self._stats_mu:
                self._stats["dropped_partition"] += 1
            return
        rolled = self._roll(self._link(request.dst, request.src, request.method))
        if rolled is None:
            with self._stats_mu:
                self._stats["dropped_loss"] += 1
            return
        delay, dup = rolled
        self._replies.push(self.clock.now() + delay, (request.msg_id, status, blob))
        if dup is not None:
            # duplicate reply: the waiter takes the first, drops the twin
            self._replies.push(self.clock.now() + dup, (request.msg_id, status, blob))

    def _drain_replies(self, batch: List[Tuple[str, str, bytes]]) -> None:
        for msg_id, status, blob in batch:
            with self._waiters_mu:
                waiter = self._waiters.get(msg_id)
            if waiter is not None:
                waiter.resolve(status, blob)

    def _note_batch(self, n: int) -> None:
        if n == 0:
            return
        with self._stats_mu:
            self._stats["delivered_batches"] += 1
            self._stats["delivered_msgs"] += n

    # -- RPC ------------------------------------------------------------- #
    def call(self, src: str, dst: str, method: str, *args, timeout: Optional[float] = None, **kwargs):
        payload = wire.dumps((args, kwargs))
        msg_id = f"{src}:{next(self._msg_seq)}"
        waiter = _Waiter(self.clock)
        with self._waiters_mu:
            self._waiters[msg_id] = waiter
        deadline = self.clock.now() + (timeout if timeout is not None else self.call_timeout)
        attempt = 0
        try:
            while True:
                attempt += 1
                if attempt > 1:
                    with self._stats_mu:
                        self._stats["retries"] += 1
                self._send(Envelope(msg_id, src, dst, method, payload, attempt=attempt))
                budget = min(self.retry_timeout * min(attempt, 8), deadline - self.clock.now())
                if budget > 0 and waiter.event.wait(budget):
                    result = waiter.take()
                    if result is not None:
                        status, blob = result
                        if status == "ok":
                            return wire.loads(blob)
                        if status == "err":
                            raise wire.loads(blob)
                        # status == "delay": back off, retry the SAME msg_id
                        self.clock.sleep(self.delay_backoff)
                if self.clock.now() >= deadline:
                    raise TimeoutError(
                        f"{src}->{dst} {method}: no reply after {attempt} attempts"
                    )
        finally:
            with self._waiters_mu:
                self._waiters.pop(msg_id, None)

    def cast(self, src: str, dst: str, method: str, *args, **kwargs) -> None:
        payload = wire.dumps((args, kwargs))
        self._send(
            Envelope(
                f"{src}:{next(self._msg_seq)}", src, dst, method, payload, needs_reply=False
            )
        )

    # -- introspection / lifecycle --------------------------------------- #
    def stats(self) -> Dict[str, float]:
        with self._stats_mu:
            out = dict(self._stats)
        out["mean_batch"] = (
            out["delivered_msgs"] / out["delivered_batches"]
            if out["delivered_batches"]
            else 0.0
        )
        return out

    def close(self) -> None:
        self._closed = True
        self._replies.stop()
        for ep in self._eps.values():
            ep.stop()
