"""repro.net — simulated transport fabric + sharded coordinator for
cluster-scale DSE (DESIGN.md §7).

Layers on top of ``repro.core``: the protocol is transport-agnostic (the
core passes ``Header`` objects where the paper passes gRPC HTTP headers);
this package supplies the fabric those headers ride on, with injectable
faults, batched delivery, and coordinator scale-out.
"""
from .transport import (
    DirectTransport,
    Envelope,
    LinkSpec,
    SimTransport,
    Transport,
    TransportError,
)
from .sharded import CoordinatorShard, DecisionBus, HashRing, ShardedCoordinator
from .cluster import NetCluster, RemoteCoordinator

__all__ = [
    "DirectTransport",
    "Envelope",
    "LinkSpec",
    "SimTransport",
    "Transport",
    "TransportError",
    "CoordinatorShard",
    "DecisionBus",
    "HashRing",
    "ShardedCoordinator",
    "NetCluster",
    "RemoteCoordinator",
]
