"""seamless-m4t-large-v2 [audio enc-dec] (arXiv:2308.11596; hf).

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. Interpreted as a
24-layer speech encoder + 24-layer text decoder (SeamlessM4T-Large v2's
symmetric backbone). The audio frontend is a STUB per the brief:
``input_specs`` provides precomputed frame embeddings (B, 1024, d_model).
Adaptation note: RoPE replaces the original sinusoidal/relative positions
(recorded in DESIGN.md); this does not change shapes or cost terms.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        num_layers=24,
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        activation="gelu",
        source_len=1024,
        notes=(
            "vocab 256206 padded to 258048 (126*2048); padded logits masked",
            "RoPE substituted for sinusoidal positions (TPU-native choice)",
            "audio frontend stubbed: precomputed frame embeddings",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=503,
        activation="gelu",
        source_len=24,
    )
