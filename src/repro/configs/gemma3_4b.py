"""gemma3-4b [dense] (hf:google/gemma-3 family; unverified tier):
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, 5:1 local:global
sliding-window attention (window 1024), 128k context. long_500k runs:
only the ~5 global layers hold full-length KV; locals use ring caches."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        activation="gelu",
        tie_embeddings=True,
        sliding_window=1024,
        global_period=6,   # every 6th layer global => 5:1 local:global
        rope_theta=1_000_000.0,
        notes=(
            "vocab 262144 = 128*2048; no padding",
            "34 layers = 5 groups of (5 local + 1 global) + 4 local tail",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        num_layers=7,          # 2 groups of (2 local + 1 global) + 1 tail
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=499,
        activation="gelu",
        tie_embeddings=True,
        sliding_window=8,
        global_period=3,
    )
