"""deepseek-v2-lite-16b [moe] (arXiv:2405.04434): MLA kv_lora=512,
27L d_model=2048 16H d_ff=1408(per expert) vocab=102400, 64 routed experts
top-6 + 2 shared, first layer dense (d_ff 10944).
NOTE: the assignment prose says "160 routed" (that is V2-full's count);
V2-Lite has 64 routed experts — we follow the structured field (64e)."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                      num_shared=2, first_k_dense=1, dense_d_ff=10944),
        notes=(
            "vocab 102400 = 50*2048; no padding",
            "MLA decode cache: compressed (c_kv 512 + k_pe 64) per token",
            "assignment prose said 160 routed (V2-full); V2-Lite=64 used",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=32,
        vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                      num_shared=1, first_k_dense=1, dense_d_ff=96),
    )
