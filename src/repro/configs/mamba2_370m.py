"""mamba2-370m [ssm] (arXiv:2405.21060; unverified tier): SSD, attn-free.
48L d_model=1024 ssm_state=128 vocab=50280."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=32,       # = d_inner/head_dim (derived; attention-free)
        num_kv_heads=32,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
        notes=("vocab 50280 padded to 51200 (25*2048)", "attention-free"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=4,
        d_model=64,
        num_heads=8,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk_size=8),
    )
