"""Assigned-architecture registry: one module per architecture, each with
``config()`` (exact published dims) and ``smoke_config()`` (reduced,
same family structure, CPU-runnable)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCHITECTURES: List[str] = [
    "seamless_m4t_large_v2",
    "yi_6b",
    "gemma_2b",
    "glm4_9b",
    "gemma3_4b",
    "zamba2_1p2b",
    "granite_moe_3b_a800m",
    "deepseek_v2_lite_16b",
    "mamba2_370m",
    "llama_3p2_vision_90b",
]

_ALIASES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "yi-6b": "yi_6b",
    "gemma-2b": "gemma_2b",
    "glm4-9b": "glm4_9b",
    "gemma3-4b": "gemma3_4b",
    "zamba2-1.2b": "zamba2_1p2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "mamba2-370m": "mamba2_370m",
    "llama-3.2-vision-90b": "llama_3p2_vision_90b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.smoke_config() if smoke else mod.config()


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCHITECTURES}
