"""llama-3.2-vision-90b [vlm] (hf:meta-llama; unverified tier):
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256, gated
cross-attention to image tokens every 5th layer (20 cross layers).
Vision frontend is a STUB: precomputed patch embeddings (B, 1024, d)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500_000.0,
        cross_attn_period=5,
        num_image_tokens=1024,
        notes=(
            "vocab 128256 padded to 129024 (63*2048)",
            "100 layers = 20 groups of (4 self + 1 gated cross)",
            "vision frontend stubbed: precomputed patch embeddings",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke",
        family="vlm",
        num_layers=4,   # 2 groups of (1 self + 1 cross)
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        cross_attn_period=2,
        num_image_tokens=16,
    )
