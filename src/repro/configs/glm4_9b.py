"""glm4-9b [dense] (hf:THUDM/glm-4-9b): RoPE, GQA.
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        notes=("vocab 151552 = 74*2048; no padding",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=512,
    )
