"""yi-6b [dense] (arXiv:2403.04652; hf): llama-arch GQA.
32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5_000_000.0,
        notes=("vocab 64000 padded to 65536 (32*2048)",),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=509,
        rope_theta=5_000_000.0,
    )
