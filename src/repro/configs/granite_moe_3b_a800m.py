"""granite-moe-3b-a800m [moe] (hf:ibm-granite): 32L d_model=1536 24H
(GQA kv=8) d_ff=512(per expert) vocab=49155, MoE 40 experts top-8.
NOTE: the assignment line also says "32 experts" in prose; we follow the
structured field (40e top-8) and record the discrepancy here."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
        notes=(
            "vocab 49155 padded to 51200 (25*2048)",
            "assignment prose said 32 experts; structured field 40e used",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=32,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32),
    )
