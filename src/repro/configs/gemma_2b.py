"""gemma-2b [dense] (arXiv:2403.08295; hf): GeGLU, head_dim=256, MQA.
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        activation="gelu",
        tie_embeddings=True,
        notes=(
            "vocab 256000 already a multiple of 2048; no padding",
            "MQA: kv_heads=1 cannot shard on model axis -> KV replicated; "
            "decode shards the cache on the sequence dim instead",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=500,
        activation="gelu",
        tie_embeddings=True,
    )
