"""zamba2-1.2b [hybrid] (arXiv:2411.15242; hf): Mamba2 backbone + SHARED
attention block. 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64. One shared attn(+MLP) block (single weight set) is applied
every 6 SSM layers (6 groups + 2 tail SSM layers)."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
        hybrid_attn_period=6,
        notes=(
            "vocab 32000 padded to 32768 (16*2048)",
            "shared attention block: one weight set, 6 application sites "
            "(each site has its own KV cache)",
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        num_layers=5,  # 2 groups of 2 + 1 tail
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk_size=8),
        hybrid_attn_period=2,
    )
