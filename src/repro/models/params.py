"""Parameter descriptor system.

Every model defines a single ``param_descs(cfg)`` tree whose leaves are
:class:`PDesc` (shape + logical axis names + init kind). From that one
source of truth we derive: real initialization (tests), allocation-free
abstract params (dry-run), and PartitionSpecs (pjit), guaranteeing the
three can never drift apart structurally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclass(frozen=True)
class PDesc:
    """Parameter leaf descriptor: shape, logical axes, init style."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_desc(x) -> bool:
    return isinstance(x, PDesc)


def stack(desc: PDesc, n: int, axis_name: Optional[str] = "layers") -> PDesc:
    """Prepend a stacked-layer dimension (scanned over; never sharded)."""
    return PDesc((n,) + desc.shape, (axis_name,) + desc.axes, desc.init, desc.scale)


def stack_tree(tree, n: int):
    return jax.tree_util.tree_map(lambda d: stack(d, n), tree, is_leaf=is_desc)


# --------------------------------------------------------------------------- #
# materialization                                                              #
# --------------------------------------------------------------------------- #
def _init_leaf(desc: PDesc, key: jax.Array, dtype) -> jax.Array:
    if desc.init == "zeros":
        return jnp.zeros(desc.shape, dtype)
    if desc.init == "ones":
        return jnp.ones(desc.shape, dtype)
    fan_in = desc.shape[-2] if len(desc.shape) >= 2 else desc.shape[-1]
    std = desc.scale / np.sqrt(max(fan_in, 1))
    if desc.init == "small":
        std = 0.01 * desc.scale
    return (jax.random.normal(key, desc.shape, jnp.float32) * std).astype(dtype)


def init_params(descs, key: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree_util.tree_flatten(descs, is_leaf=is_desc)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(descs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree — no allocation (dry-run path)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), descs, is_leaf=is_desc
    )


# --------------------------------------------------------------------------- #
# sharding resolution                                                          #
# --------------------------------------------------------------------------- #
#: logical axes earlier in this list claim mesh axes first (e.g. kv_heads
#: beats the seq fallback for decode caches; experts beats expert_ffn).
_PRIORITY = {
    "vocab": 0, "heads": 0, "kv_heads": 0, "ffn": 0, "experts": 0,
    "batch": 1, "embed": 2, "expert_ffn": 2, "seq": 3,
}


def resolve_spec(
    desc: PDesc,
    rules: Mapping[str, Tuple[str, ...]],
    mesh_axis_sizes: Mapping[str, int],
) -> PartitionSpec:
    """Logical axes -> PartitionSpec. Assignments that do not divide the
    dimension or that reuse a consumed mesh axis are dropped; contested mesh
    axes go to the highest-priority logical axis (fallback chains)."""
    used: set = set()
    out: list = [None] * len(desc.shape)
    order = sorted(
        range(len(desc.shape)),
        key=lambda i: _PRIORITY.get(desc.axes[i], 9) if desc.axes[i] else 99,
    )
    for i in order:
        dim, logical = desc.shape[i], desc.axes[i]
        if logical is None or logical not in rules:
            continue
        mesh_axes = rules[logical]
        mesh_axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        total = 1
        for a in mesh_axes:
            total *= mesh_axis_sizes.get(a, 1)
        if mesh_axes and total > 1 and dim % total == 0:
            out[i] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
            used.update(mesh_axes)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def resolve_specs(descs, rules, mesh_axis_sizes):
    return jax.tree_util.tree_map(
        lambda d: resolve_spec(d, rules, mesh_axis_sizes), descs, is_leaf=is_desc
    )


def param_count(descs) -> int:
    leaves = jax.tree_util.tree_leaves(descs, is_leaf=is_desc)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def param_bytes(descs, bytes_per_param: int = 2) -> int:
    return param_count(descs) * bytes_per_param
