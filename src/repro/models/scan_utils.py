"""Scan-unroll context for dry-run cost probes.

XLA's HloCostAnalysis counts a while-loop body once regardless of trip
count; the dry-run's shallow cost probes therefore lower with fully
unrolled stacks. Production paths keep rolled scans (compact HLO).
"""
from __future__ import annotations

import jax

_SCAN_UNROLL: bool = False


class scan_unroll:
    def __enter__(self):
        global _SCAN_UNROLL
        self._prev = _SCAN_UNROLL
        _SCAN_UNROLL = True

    def __exit__(self, *exc):
        global _SCAN_UNROLL
        _SCAN_UNROLL = self._prev


def _scan(body, init, xs, unrollable: bool = True):
    """unrollable=False: keep rolled even under the probe context — used for
    inner recurrences whose per-iteration cost is negligible (e.g. the SSD
    chunk-state recurrence: its einsums are hoisted outside the scan), where
    unrolling only explodes compile time without changing measured cost."""
    unroll = True if (_SCAN_UNROLL and unrollable) else 1
    return jax.lax.scan(body, init, xs, unroll=unroll)
