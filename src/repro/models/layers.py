"""Transformer building blocks (pure JAX, GSPMD-friendly).

Conventions:
  * activations: x (B, S, D); masks are built from iota comparisons inside
    attention (never materialized globally);
  * GQA einsums keep the (kv_heads, group) split so sharding by kv_heads
    propagates: q (B,S,N,G,H), k/v (B,T,N,H);
  * decode caches are (B, Smax, N, H) ring/linear buffers updated with
    dynamic_update_slice at the current index.

Logical sharding axis names used in descriptors: "embed", "heads",
"kv_heads", "head_dim", "ffn", "vocab", "experts", "expert_ffn",
"layers" (scan dim, never sharded), "state", "batch", "seq".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .params import PDesc
from .tuning import constrain_replicated_heads, constrain_seq_sharded, get_tuning

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# norms / rope                                                                 #
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    return (x.astype(F32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + w.astype(x.dtype))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H) with H even; positions broadcastable to (..., S)."""
    h = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, h, 2, dtype=F32) / h))
    angles = positions[..., None].astype(F32) * freqs  # (..., S, H/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : h // 2], x[..., h // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _soft_cap(logits: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(logits / cap) * cap if cap > 0 else logits


# --------------------------------------------------------------------------- #
# attention                                                                    #
# --------------------------------------------------------------------------- #
def attn_descs(cfg: ModelConfig, cross: bool = False) -> Dict[str, PDesc]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    descs = {
        "wq": PDesc((d, nq, hd), ("embed", "heads", None)),
        "wk": PDesc((d, nkv, hd), ("embed", "kv_heads", None)),
        "wv": PDesc((d, nkv, hd), ("embed", "kv_heads", None)),
        "wo": PDesc((nq, hd, d), ("heads", None, "embed")),
    }
    if cross:
        descs["gate"] = PDesc((1,), (None,), init="zeros")  # tanh-gated (VLM)
    return descs


def _sdpa(
    q: jax.Array,        # (B, S, N, H)  — N = full query heads
    k: jax.Array,        # (B, T, N, H)  — kv repeated to N (GQA)
    v: jax.Array,        # (B, T, N, H)
    mask: Optional[jax.Array],  # broadcastable to (B, N, S, T) or None
    softcap: float,
) -> jax.Array:
    # NOTE (sharding): GQA is computed in repeat-kv form on purpose — a
    # (kv_heads, groups) split of the head dim is unshardable whenever
    # kv_heads < |model| (GSPMD would replicate the S x T logits). Repeating
    # K/V keeps every attention tensor sharded on the full head dim.
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bsnh,btnh->bnst", q, k).astype(F32) * scale
    logits = _soft_cap(logits, softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bnst,btnh->bsnh", probs, v)


def attention(
    p: Dict[str, jax.Array],
    x: jax.Array,                       # (B, S, D)
    cfg: ModelConfig,
    positions: jax.Array,               # (B, S) absolute positions of x
    *,
    window: Optional[int] = None,       # sliding-window size (local attn)
    cache: Optional[Dict[str, jax.Array]] = None,  # decode: {"k","v"} (B,Smax,N,H)
    cache_index: Optional[jax.Array] = None,       # scalar int32 write offset
    ring: bool = False,                 # cache is a ring buffer of size window
    cross_src: Optional[jax.Array] = None,         # (B, Ssrc, D) encoder/image
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = nq // nkv

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    kv_in = cross_src if cross_src is not None else x
    k = jnp.einsum("bsd,dnh->bsnh", kv_in, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", kv_in, p["wv"])

    if cross_src is None:
        q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta).transpose(0, 2, 1, 3)
        k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta).transpose(0, 2, 1, 3)

    new_cache = None
    if cross_src is not None:
        kv_pos = None
        mask = None  # full attention over the (stub) modality tokens
        t_len = cross_src.shape[1]
    elif cache is not None:
        smax = cache["k"].shape[1]
        if ring:
            idx = (cache_index % smax).astype(jnp.int32)
        else:
            idx = cache_index.astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        if get_tuning().decode_seq_constraint:
            # flash-decode sharding: K/V stay sequence-sharded AND q is
            # replicated over the model axis (q is (B,1,N,H) — tiny), so
            # QK^T/PV contract locally per T-shard; GSPMD inserts only
            # small stat/partial-sum all-reduces instead of gathering the
            # repeated cache per layer.
            ck = constrain_seq_sharded(ck, 1)
            cv = constrain_seq_sharded(cv, 1)
            q = constrain_replicated_heads(q)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        t_len = smax
        slot = jnp.arange(smax, dtype=jnp.int32)
        if ring:
            # slot holds absolute position cache_index - ((idx - slot) mod smax)
            age = (idx - slot) % smax
            abs_pos = cache_index - age
            valid = (abs_pos >= 0) & (abs_pos <= cache_index)
            if window is not None:
                valid &= abs_pos > cache_index - window
            mask = valid[None, None, None, :]
        else:
            valid = slot <= cache_index
            if window is not None:
                valid &= slot > cache_index - window
            mask = valid[None, None, None, :]
    else:
        t_len = S
        qpos = positions[:, None, :, None]                # (B,1,S,1)
        kpos = positions[:, None, None, :]                # (B,1,1,T)
        mask = jnp.ones((B, 1, S, S), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window

    if cache is not None and cross_src is None and get_tuning().decode_seq_constraint:
        # flash-decode: NO kv repeat (the repeat is a broadcast GSPMD would
        # shard on heads, forcing a full seq all-gather of the cache).
        # q is replicated, K/V stay seq-sharded; the grouped einsum
        # contracts locally per T-shard and GSPMD inserts only small
        # softmax-stat / partial-sum all-reduces.
        qg = q.reshape(B, S, nkv, groups, hd)
        scale = 1.0 / np.sqrt(hd)
        logits = jnp.einsum("bsngh,btnh->bngst", qg, k).astype(F32) * scale
        logits = _soft_cap(logits, cfg.logit_softcap)
        if mask is not None:
            logits = jnp.where(mask[:, :, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bngst,btnh->bsngh", probs, v).reshape(B, S, nq, hd)
    else:
        if groups > 1:
            k = jnp.repeat(k, groups, axis=2)
            v = jnp.repeat(v, groups, axis=2)
        out = _sdpa(q, k, v, mask, cfg.logit_softcap)
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return out, new_cache


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V2 multi-head latent attention)                                #
# --------------------------------------------------------------------------- #
def mla_descs(cfg: ModelConfig) -> Dict[str, PDesc]:
    m, d, nq = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    descs: Dict[str, PDesc] = {}
    if m.q_lora_rank:
        descs["w_dq"] = PDesc((d, m.q_lora_rank), ("embed", None))
        descs["w_uq"] = PDesc((m.q_lora_rank, nq, qk), (None, "heads", None))
    else:
        descs["w_q"] = PDesc((d, nq, qk), ("embed", "heads", None))
    descs["w_dkv"] = PDesc((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None))
    descs["w_uk"] = PDesc((m.kv_lora_rank, nq, m.qk_nope_head_dim), (None, "heads", None))
    descs["w_uv"] = PDesc((m.kv_lora_rank, nq, m.v_head_dim), (None, "heads", None))
    descs["wo"] = PDesc((nq, m.v_head_dim, d), ("heads", None, "embed"))
    return descs


def mla_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,   # {"ckv": (B,Smax,R), "kpe": (B,Smax,P)}
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mla
    B, S, D = x.shape
    nq = cfg.num_heads

    if m.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        q = jnp.einsum("bsr,rnh->bsnh", q, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dnh->bsnh", x, p["w_q"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_pe = q[..., m.qk_nope_head_dim :]
    q_pe = rope(q_pe.transpose(0, 2, 1, 3), positions[:, None, :], cfg.rope_theta).transpose(0, 2, 1, 3)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv, k_pe = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank :]
    k_pe = rope(k_pe, positions, cfg.rope_theta)  # (B,S,P): shared across heads

    new_cache = None
    if cache is not None:
        idx = cache_index.astype(jnp.int32)
        cckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, idx, 0))
        ckpe = jax.lax.dynamic_update_slice(cache["kpe"], k_pe, (0, idx, 0))
        new_cache = {"ckv": cckv, "kpe": ckpe}
        ckv, k_pe = cckv, ckpe
        t = ckv.shape[1]
        valid = jnp.arange(t, dtype=jnp.int32) <= cache_index
        mask = valid[None, None, :, None]  # (1,1,T,1) -> used below as (B,N,S,T)
        mask = valid[None, None, None, :]
    else:
        qpos = positions[:, None, :, None]
        kpos = positions[:, None, None, :]
        mask = kpos <= qpos  # (B,1,S,T)

    # expand compressed cache: k_nope (B,T,N,Hn), v (B,T,N,Hv)
    k_nope = jnp.einsum("btr,rnh->btnh", ckv, p["w_uk"])
    val = jnp.einsum("btr,rnh->btnh", ckv, p["w_uv"])

    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (
        jnp.einsum("bsnh,btnh->bnst", q_nope, k_nope)
        + jnp.einsum("bsnh,bth->bnst", q_pe, k_pe)
    ).astype(F32) * scale
    logits = jnp.where(mask if mask.ndim == 4 else mask[:, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bnst,btnh->bsnh", probs, val)
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return out, new_cache


# --------------------------------------------------------------------------- #
# MLPs                                                                         #
# --------------------------------------------------------------------------- #
def mlp_descs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, PDesc]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "wi_gate": PDesc((d, f), ("embed", "ffn")),
        "wi_up": PDesc((d, f), ("embed", "ffn")),
        "wo": PDesc((f, d), ("ffn", "embed")),
    }


def mlp(p: Dict[str, jax.Array], x: jax.Array, activation: str) -> jax.Array:
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    h = act(jnp.einsum("bsd,df->bsf", x, p["wi_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, p["wi_up"]
    )
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# --------------------------------------------------------------------------- #
# MoE (GShard-style grouped einsum dispatch; EP-a2a path lives in             #
# parallel/ep_moe.py as a perf alternative)                                   #
# --------------------------------------------------------------------------- #
def moe_descs(cfg: ModelConfig) -> Dict[str, PDesc]:
    mo, d = cfg.moe, cfg.d_model
    descs = {
        "router": PDesc((d, mo.num_experts), ("embed", None), init="small"),
        "w_gate": PDesc((mo.num_experts, d, mo.d_expert), ("experts", "embed", "expert_ffn")),
        "w_up": PDesc((mo.num_experts, d, mo.d_expert), ("experts", "embed", "expert_ffn")),
        "w_down": PDesc((mo.num_experts, mo.d_expert, d), ("experts", "expert_ffn", "embed")),
    }
    if mo.num_shared:
        descs["shared"] = mlp_descs(cfg, d_ff=mo.num_shared * mo.d_expert)
    return descs


def moe(
    p: Dict[str, jax.Array],
    x: jax.Array,                    # (B, S, D)
    cfg: ModelConfig,
    group_size: int = 2048,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss). Token groups bound the dispatch tensor to
    (G, Tg, E, C) with Tg = group_size (GShard §3.2); groups shard over the
    batch axes, experts over the model axis."""
    mo = cfg.moe
    if get_tuning().moe_impl == "ep":
        from ..parallel.ep_moe import ep_moe, get_ep_mesh

        if get_ep_mesh() is not None:
            out, aux = ep_moe(
                {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}, x, cfg
            )
            if mo.num_shared:
                out = out + mlp(p["shared"], x, cfg.activation)
            return out, aux

    B, S, D = x.shape
    T = B * S
    tg = min(group_size, T)
    G = T // tg
    xf = x.reshape(G, tg, D)

    logits = jnp.einsum("gtd,de->gte", xf, p["router"]).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, ids = jax.lax.top_k(probs, mo.top_k)            # (G,tg,k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): mean prob vs mean assignment per expert
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        (jax.nn.one_hot(ids, mo.num_experts, dtype=F32)).sum(2), axis=(0, 1)
    ) / mo.top_k
    aux = mo.num_experts * jnp.sum(me * ce) * mo.router_aux_weight

    capacity = int(np.ceil(tg * mo.top_k / mo.num_experts * mo.capacity_factor))
    onehot = jax.nn.one_hot(ids, mo.num_experts, dtype=F32)  # (G,tg,k,E)
    # position of each (token, slot) within its expert, in (t, k) priority order
    flat = onehot.reshape(G, tg * mo.top_k, mo.num_experts)
    pos = (jnp.cumsum(flat, axis=1) - flat).astype(jnp.int32)  # (G,tg*k,E)
    pos = pos.reshape(G, tg, mo.top_k, mo.num_experts)
    # slot of each (token, k) within its CHOSEN expert; overflow slots drop
    pos_sel = jnp.take_along_axis(pos, ids[..., None], axis=-1)[..., 0]  # (G,tg,k)
    keep = (pos_sel < capacity).astype(x.dtype)
    oh_e = jax.nn.one_hot(ids, mo.num_experts, dtype=x.dtype) * keep[..., None]
    oh_c = jax.nn.one_hot(pos_sel, capacity, dtype=x.dtype)   # (G,tg,k,C)
    # contract k: never materializes the 5-D (t,k,E,C) tensor
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_w.astype(x.dtype), oh_e, oh_c)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xf)          # (G,E,C,D)
    act = jax.nn.gelu if cfg.activation == "gelu" else jax.nn.silu
    h = act(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["w_up"]
    )
    xout = jnp.einsum("gecf,efd->gecd", h, p["w_down"])       # (G,E,C,D)
    out = jnp.einsum("gtec,gecd->gtd", combine, xout).reshape(B, S, D)

    if mo.num_shared:
        out = out + mlp(p["shared"], x, cfg.activation)
    return out, aux
