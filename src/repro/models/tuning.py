"""Performance-tuning flags (the §Perf hillclimb knobs).

A context-var style switchboard so the dry-run can lower the SAME cell in
baseline and optimized variants without touching model call signatures:

  decode_seq_constraint — pin decode K/V (and MLA compressed caches) to
      sequence-sharding via with_sharding_constraint, preventing GSPMD's
      involuntary full rematerialization when kv_heads cannot divide the
      model axis (observed on yi-6b decode_32k: the partitioner re-shards
      the 2x(B,S,N,H) cache per layer);
  loss_chunk — compute the LM head + cross-entropy over sequence chunks of
      this size (0 = off), bounding the fp32 logits working set;
  microbatch — grad-accumulation microbatches per step (1 = off), dividing
      saved-activation memory.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Tuning:
    decode_seq_constraint: bool = False
    loss_chunk: int = 0
    microbatch: int = 1
    # Pin (B, S, D) activations to batch-over-data at every block boundary.
    # Under FSDP (weights' embed dim sharded over "data") GSPMD otherwise
    # resolves the batch-vs-weight contest by REPLICATING batch and
    # sharding activations on d_model — measured 12.2 TB/chip of f32
    # full-batch all-reduces on llama-90B train (§Perf B3).
    constrain_activations: bool = False
    # "einsum" (GShard grouped dispatch, GSPMD-native) or "ep"
    # (shard_map all_to_all expert parallelism, parallel/ep_moe.py)
    moe_impl: str = "einsum"


_CURRENT = Tuning()


def get_tuning() -> Tuning:
    return _CURRENT


class tuning:
    def __init__(self, **kw) -> None:
        self._kw = kw

    def __enter__(self) -> Tuning:
        global _CURRENT
        self._prev = _CURRENT
        _CURRENT = replace(_CURRENT, **self._kw)
        return _CURRENT

    def __exit__(self, *exc) -> None:
        global _CURRENT
        _CURRENT = self._prev


def constrain(x, entries):
    """Best-effort with_sharding_constraint under the ambient mesh context.
    ``entries``: one per dim — "model"/axis names, None (replicated), or
    "free" (unconstrained). No-ops (via exception) when there is no mesh
    context or the spec does not divide — so smoke tests and non-tuned
    paths are unaffected; only tuned dry-run lowers activate it."""
    import jax
    from jax.sharding import PartitionSpec as P

    try:
        spec = tuple(P.UNCONSTRAINED if e == "free" else e for e in entries)
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_seq_sharded(x, seq_axis: int):
    entries = ["free"] * x.ndim
    entries[seq_axis] = "model"
    for i in range(x.ndim):
        if i != seq_axis and i != 0:
            entries[i] = None  # model axis consumed by seq; rest replicated
    return constrain(x, entries)


def constrain_batch_sharded(x):
    """Pin dim0 to the batch mesh axes (pod+data when present), leaving the
    rest replicated (Megatron-style activation layout: (B/dp, S, D-full))."""
    if not get_tuning().constrain_activations:
        return x
    for batch_axes in (("pod", "data"), "data"):
        y = constrain(x, (batch_axes,) + (None,) * (x.ndim - 1))
        if y is not x:
            return y
    return x


def constrain_replicated_heads(q):
    """Decode flash-decode scheme: q is (B, 1, N, H) and tiny — replicating
    it over the model axis lets QK^T run against sequence-sharded K/V with
    no resharding; softmax and PV reduce over the sharded T dim with small
    stat all-reduces instead of gathering the cache."""
    return constrain(q, ("free", None, None, None))
