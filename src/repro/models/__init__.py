"""JAX model substrate: configs, parameter descriptors, forward/decode."""
from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, shape_by_name
from .params import (
    PDesc,
    abstract_params,
    init_params,
    param_bytes,
    param_count,
    resolve_spec,
    resolve_specs,
    stack,
    stack_tree,
)
from .transformer import (
    cache_descs,
    decode_step,
    forward,
    lm_loss,
    param_descs,
)

__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "shape_by_name",
    "PDesc", "abstract_params", "init_params", "param_bytes", "param_count",
    "resolve_spec", "resolve_specs", "stack", "stack_tree",
    "cache_descs", "decode_step", "forward", "lm_loss", "param_descs",
]
