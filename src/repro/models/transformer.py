"""Model assembly for the 10 assigned architectures.

Every architecture is described by the same ``ModelConfig``; this module
builds (a) the parameter-descriptor tree, (b) ``forward`` for train/prefill,
(c) ``decode_step`` against explicit caches, and (d) the LM loss. Stacks use
``lax.scan`` over layer-stacked parameters; heterogeneous stacks (gemma3
local:global, zamba2 shared-attention, vision cross-attention) scan over
*groups* whose inner structure is homogeneous, so the HLO stays compact at
any depth.

Family map:
  dense / moe    -> forward_dense     (MLA and MoE are per-block options)
  gemma3         -> grouped local/global stack (ring caches for local layers)
  ssm            -> forward_ssm       (Mamba-2 SSD)
  hybrid         -> forward_hybrid    (zamba2: shared attn block every N SSM layers)
  encdec         -> forward_encdec    (seamless: stub audio frames -> encoder)
  vlm            -> forward_vlm       (llama-3.2-vision: gated cross-attn groups)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    attention,
    attn_descs,
    mla_attention,
    mla_descs,
    mlp,
    mlp_descs,
    moe,
    moe_descs,
    rms_norm,
)
from .params import PDesc, stack_tree
from .scan_utils import _scan, scan_unroll
from .ssm import mamba2_mixer, ssm_descs
from .tuning import constrain_batch_sharded, get_tuning

F32 = jnp.float32


# --------------------------------------------------------------------------- #
# blocks                                                                       #
# --------------------------------------------------------------------------- #
def _block_descs(cfg: ModelConfig, *, kind: str, dense_ff: Optional[int] = None) -> Dict:
    """kind: attn | local_attn | mla | attn_moe | mla_moe | ssm | cross | attn_dense"""
    d = cfg.d_model
    descs: Dict[str, Any] = {"ln1": PDesc((d,), ("embed",), init="zeros")}
    if kind == "ssm":
        descs["mixer"] = ssm_descs(cfg)
        return descs  # mamba block has its own epilogue norm
    if kind == "cross":
        descs["attn"] = attn_descs(cfg, cross=True)
        descs["ln2"] = PDesc((d,), ("embed",), init="zeros")
        descs["mlp"] = mlp_descs(cfg)
        descs["mlp_gate"] = PDesc((1,), (None,), init="zeros")
        return descs
    descs["attn"] = mla_descs(cfg) if kind.startswith("mla") else attn_descs(cfg)
    descs["ln2"] = PDesc((d,), ("embed",), init="zeros")
    if kind.endswith("moe"):
        descs["moe"] = moe_descs(cfg)
    else:
        descs["mlp"] = mlp_descs(cfg, d_ff=dense_ff)
    return descs


def _block_apply(
    cfg: ModelConfig,
    lp: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str,
    window: Optional[int] = None,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    ring: bool = False,
    cross_src: Optional[jax.Array] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    x = constrain_batch_sharded(x)  # §Perf B3 knob; no-op unless tuned on
    aux = jnp.zeros((), F32)
    if kind == "ssm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, new_cache = mamba2_mixer(lp["mixer"], h, cfg, cache=cache)
        return x + out, new_cache, aux

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "cross":
        out, new_cache = attention(
            lp["attn"], h, cfg, positions, cross_src=cross_src, causal=False
        )
        out = out * jnp.tanh(lp["attn"]["gate"].astype(x.dtype))
        x = x + out
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        m = mlp(lp["mlp"], h2, cfg.activation)
        m = m * jnp.tanh(lp["mlp_gate"].astype(x.dtype))
        return x + m, None, aux

    if kind.startswith("mla"):
        out, new_cache = mla_attention(
            lp["attn"], h, cfg, positions, cache=cache, cache_index=cache_index
        )
    else:
        out, new_cache = attention(
            lp["attn"],
            h,
            cfg,
            positions,
            window=window,
            cache=cache,
            cache_index=cache_index,
            ring=ring,
            cross_src=cross_src,
            causal=causal,
        )
    x = x + out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if kind.endswith("moe"):
        m, aux = moe(lp["moe"], h2, cfg)
    else:
        m = mlp(lp["mlp"], h2, cfg.activation)
    return x + m, new_cache, aux


def _maybe_remat(fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------- #
# embedding / head                                                             #
# --------------------------------------------------------------------------- #
def _embed_descs(cfg: ModelConfig) -> Dict:
    descs = {
        "embed": PDesc((cfg.vocab_padded, cfg.d_model), ("vocab", "embed")),
        "ln_f": PDesc((cfg.d_model,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        descs["lm_head"] = PDesc((cfg.d_model, cfg.vocab_padded), ("embed", "vocab"))
    return descs


def _embed(cfg: ModelConfig, params: Dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.activation == "gelu":  # gemma family scales embeddings
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def apply_head(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _logits(cfg: ModelConfig, params: Dict, x: jax.Array, last_only: bool = False) -> jax.Array:
    if last_only:
        x = x[:, -1:]
    elif get_tuning().loss_chunk:
        # §Perf knob: leave hidden states; the head is applied chunk-wise
        # inside chunked_lm_loss to bound the fp32 logits working set.
        return x
    return apply_head(cfg, params, x)


def chunked_lm_loss(
    cfg: ModelConfig,
    params: Dict,
    hidden: jax.Array,   # (B, S, D) — forward output under loss_chunk tuning
    labels: jax.Array,   # (B, S)
    aux: jax.Array,
    chunk: int,
) -> jax.Array:
    """LM head + cross-entropy over sequence chunks (rematerialized): the
    (B, chunk, V) fp32 logits are the only head-sized live tensor."""
    B, S, D = hidden.shape
    if S % chunk != 0:
        return lm_loss(cfg, apply_head(cfg, params, hidden), labels, aux)
    nc = S // chunk
    xr = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    yr = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(carry, xs):
        xc, yc = xs
        logits = apply_head(cfg, params, xc).astype(F32)
        if cfg.vocab_padded != cfg.vocab_size:
            pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
            logits = jnp.where(pad[None, None, :], -1e30, logits)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - ll), None

    body = jax.checkpoint(body)
    total, _ = _scan(body, jnp.zeros((), F32), (xr, yr))
    return total / (B * S) + aux


# --------------------------------------------------------------------------- #
# family: dense / moe / gemma3                                                 #
# --------------------------------------------------------------------------- #
def _dense_plan(cfg: ModelConfig) -> Dict:
    """Segments of homogeneous stacks for dense/moe/mla archs."""
    if cfg.global_period:  # gemma3: groups of (p-1) local + 1 global, + tail
        p = cfg.global_period
        n_groups = cfg.num_layers // p
        tail = cfg.num_layers - n_groups * p
        return {"kind": "gemma3", "groups": n_groups, "locals": p - 1, "tail": tail}
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return {
            "kind": "deepseek",
            "dense": cfg.moe.first_k_dense,
            "moe": cfg.num_layers - cfg.moe.first_k_dense,
        }
    return {"kind": "flat", "layers": cfg.num_layers}


def _attn_kind(cfg: ModelConfig) -> str:
    if cfg.mla is not None:
        return "mla_moe" if cfg.moe is not None else "mla"
    return "attn_moe" if cfg.moe is not None else "attn"


def dense_descs(cfg: ModelConfig) -> Dict:
    plan = _dense_plan(cfg)
    descs = _embed_descs(cfg)
    if plan["kind"] == "flat":
        descs["layers"] = stack_tree(_block_descs(cfg, kind=_attn_kind(cfg)), plan["layers"])
    elif plan["kind"] == "deepseek":
        dense_block = _block_descs(cfg, kind="mla", dense_ff=cfg.moe.dense_d_ff)
        descs["dense_layers"] = stack_tree(dense_block, plan["dense"])
        descs["moe_layers"] = stack_tree(_block_descs(cfg, kind="mla_moe"), plan["moe"])
    else:  # gemma3
        local = _block_descs(cfg, kind="attn")
        descs["group_locals"] = stack_tree(stack_tree(local, plan["locals"]), plan["groups"])
        descs["group_global"] = stack_tree(_block_descs(cfg, kind="attn"), plan["groups"])
        if plan["tail"]:
            descs["tail_locals"] = stack_tree(local, plan["tail"])
    return descs


def _scan_stack(
    cfg: ModelConfig,
    stacked: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str,
    window: Optional[int] = None,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    ring: bool = False,
    causal: bool = True,
    remat: str = "none",
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    def body(carry, xs):
        h, aux = carry
        lp, c = xs
        h, new_c, a = _block_apply(
            cfg, lp, h, positions,
            kind=kind, window=window, cache=c, cache_index=cache_index,
            ring=ring, causal=causal,
        )
        return (h, aux + a), new_c

    body = _maybe_remat(body, remat)
    (x, aux), new_cache = _scan(body, (x, jnp.zeros((), F32)), (stacked, cache))
    return x, new_cache, aux


def forward_dense(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    last_only: bool = False,
    *,
    remat: str = "none",
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Train/prefill when cache is None; single-token decode otherwise."""
    plan = _dense_plan(cfg)
    B, S = tokens.shape
    if cache is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        decode = False
    else:
        positions = jnp.broadcast_to(cache_index[None, None].astype(jnp.int32), (B, S))
        decode = True
    x = _embed(cfg, params, tokens)
    aux = jnp.zeros((), F32)

    if plan["kind"] == "flat":
        x, new_cache, aux = _scan_stack(
            cfg, params["layers"], x, positions,
            kind=_attn_kind(cfg),
            cache=cache["layers"] if decode else None,
            cache_index=cache_index, remat=remat,
        )
        new_cache = {"layers": new_cache} if decode else None
    elif plan["kind"] == "deepseek":
        x, nc_d, a1 = _scan_stack(
            cfg, params["dense_layers"], x, positions, kind="mla",
            cache=cache["dense_layers"] if decode else None,
            cache_index=cache_index, remat=remat,
        )
        x, nc_m, a2 = _scan_stack(
            cfg, params["moe_layers"], x, positions, kind="mla_moe",
            cache=cache["moe_layers"] if decode else None,
            cache_index=cache_index, remat=remat,
        )
        aux = a1 + a2
        new_cache = {"dense_layers": nc_d, "moe_layers": nc_m} if decode else None
    else:  # gemma3 grouped local/global
        def group_body(carry, xs):
            h, aux = carry
            gl, gg, cl, cg = xs
            h, ncl, a1 = _scan_stack(
                cfg, gl, h, positions, kind="attn", window=cfg.sliding_window,
                cache=cl, cache_index=cache_index, ring=decode,
            )
            h, ncg, a2 = _block_apply(
                cfg, gg, h, positions, kind="attn",
                cache=cg, cache_index=cache_index,
            )
            return (h, aux + a1 + a2), (ncl, ncg)

        group_body = _maybe_remat(group_body, remat)
        xs = (
            params["group_locals"], params["group_global"],
            cache["group_locals"] if decode else None,
            cache["group_global"] if decode else None,
        )
        (x, aux), (ncl, ncg) = _scan(group_body, (x, aux), xs)
        nct = None
        if plan["tail"]:
            x, nct, a3 = _scan_stack(
                cfg, params["tail_locals"], x, positions,
                kind="attn", window=cfg.sliding_window,
                cache=cache["tail_locals"] if decode else None,
                cache_index=cache_index, ring=decode, remat=remat,
            )
            aux = aux + a3
        new_cache = (
            {"group_locals": ncl, "group_global": ncg, "tail_locals": nct}
            if decode else None
        )
        if decode and not plan["tail"]:
            new_cache.pop("tail_locals")

    logits = _logits(cfg, params, x, last_only)
    return logits, new_cache, aux


# --------------------------------------------------------------------------- #
# family: ssm / hybrid                                                         #
# --------------------------------------------------------------------------- #
def ssm_descs_tree(cfg: ModelConfig) -> Dict:
    descs = _embed_descs(cfg)
    descs["layers"] = stack_tree(_block_descs(cfg, kind="ssm"), cfg.num_layers)
    return descs


def hybrid_descs(cfg: ModelConfig) -> Dict:
    p = cfg.hybrid_attn_period
    n_groups = cfg.num_layers // p
    tail = cfg.num_layers - n_groups * p
    descs = _embed_descs(cfg)
    descs["shared_attn"] = _block_descs(cfg, kind="attn")  # ONE shared block
    descs["group_ssm"] = stack_tree(stack_tree(_block_descs(cfg, kind="ssm"), p), n_groups)
    if tail:
        descs["tail_ssm"] = stack_tree(_block_descs(cfg, kind="ssm"), tail)
    return descs


def forward_ssm(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    last_only: bool = False,
    *,
    remat: str = "none",
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    B, S = tokens.shape
    decode = cache is not None
    positions = (
        jnp.broadcast_to(cache_index[None, None].astype(jnp.int32), (B, S))
        if decode
        else jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    )
    x = _embed(cfg, params, tokens)
    x, new_cache, aux = _scan_stack(
        cfg, params["layers"], x, positions, kind="ssm",
        cache=cache["layers"] if decode else None,
        cache_index=cache_index, remat=remat,
    )
    return _logits(cfg, params, x, last_only), ({"layers": new_cache} if decode else None), aux


def forward_hybrid(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    last_only: bool = False,
    *,
    remat: str = "none",
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    p = cfg.hybrid_attn_period
    n_groups = cfg.num_layers // p
    tail = cfg.num_layers - n_groups * p
    B, S = tokens.shape
    decode = cache is not None
    positions = (
        jnp.broadcast_to(cache_index[None, None].astype(jnp.int32), (B, S))
        if decode
        else jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    )
    x = _embed(cfg, params, tokens)
    shared = params["shared_attn"]

    def group_body(carry, xs):
        h, aux = carry
        gssm, c_attn, c_ssm = xs
        # shared attention block (weights shared; per-site KV cache)
        h, nc_attn, a1 = _block_apply(
            cfg, shared, h, positions, kind="attn",
            cache=c_attn, cache_index=cache_index,
        )
        h, nc_ssm, a2 = _scan_stack(
            cfg, gssm, h, positions, kind="ssm",
            cache=c_ssm, cache_index=cache_index,
        )
        return (h, aux + a1 + a2), (nc_attn, nc_ssm)

    group_body = _maybe_remat(group_body, remat)
    xs = (
        params["group_ssm"],
        cache["shared_attn"] if decode else None,
        cache["group_ssm"] if decode else None,
    )
    (x, aux), (nca, ncs) = _scan(group_body, (x, jnp.zeros((), F32)), xs)
    nct = None
    if tail:
        x, nct, a3 = _scan_stack(
            cfg, params["tail_ssm"], x, positions, kind="ssm",
            cache=cache["tail_ssm"] if decode else None,
            cache_index=cache_index, remat=remat,
        )
        aux = aux + a3
    new_cache = None
    if decode:
        new_cache = {"shared_attn": nca, "group_ssm": ncs}
        if tail:
            new_cache["tail_ssm"] = nct
    return _logits(cfg, params, x, last_only), new_cache, aux


# --------------------------------------------------------------------------- #
# family: encoder-decoder (seamless-m4t)                                       #
# --------------------------------------------------------------------------- #
def encdec_descs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    descs = _embed_descs(cfg)
    enc_block = _block_descs(cfg, kind="attn")
    descs["encoder"] = stack_tree(enc_block, cfg.encoder_layers)
    dec_block = _block_descs(cfg, kind="attn")
    dec_block["ln_cross"] = PDesc((d,), ("embed",), init="zeros")
    dec_block["cross_attn"] = attn_descs(cfg)
    descs["decoder"] = stack_tree(dec_block, cfg.num_layers)
    return descs


def _decoder_block(cfg, lp, x, positions, enc_out, cache, cache_index):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    out, new_cache = attention(
        lp["attn"], h, cfg, positions, cache=cache, cache_index=cache_index
    )
    x = x + out
    hc = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
    out, _ = attention(lp["cross_attn"], hc, cfg, positions, cross_src=enc_out, causal=False)
    x = x + out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp(lp["mlp"], h2, cfg.activation), new_cache


def forward_encdec(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    last_only: bool = False,            # decoder text tokens (B, S)
    *,
    frames: jax.Array,            # stub audio frontend output (B, Ssrc, D)
    remat: str = "none",
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,  # reuse encoder output during decode
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    B, S = tokens.shape
    decode = cache is not None

    if enc_out is None:
        src_pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2]
        )
        def enc_body(carry, lp):
            h, _ = carry
            h, _, _ = _block_apply(cfg, lp, h, src_pos, kind="attn", causal=False)
            return (h, jnp.zeros((), F32)), None
        enc_body = _maybe_remat(enc_body, remat)
        (enc_out, _), _ = _scan(enc_body, (frames, jnp.zeros((), F32)), params["encoder"])

    positions = (
        jnp.broadcast_to(cache_index[None, None].astype(jnp.int32), (B, S))
        if decode
        else jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    )
    x = _embed(cfg, params, tokens)

    def dec_body(carry, xs):
        h, _ = carry
        lp, c = xs
        h, nc = _decoder_block(cfg, lp, h, positions, enc_out, c, cache_index)
        return (h, jnp.zeros((), F32)), nc

    dec_body = _maybe_remat(dec_body, remat)
    (x, _), new_cache = _scan(
        dec_body, (x, jnp.zeros((), F32)),
        (params["decoder"], cache["decoder"] if decode else None),
    )
    nc = {"decoder": new_cache, "enc_out": enc_out} if decode else None
    return _logits(cfg, params, x, last_only), nc, jnp.zeros((), F32)


# --------------------------------------------------------------------------- #
# family: vision-language (llama-3.2-vision)                                   #
# --------------------------------------------------------------------------- #
def vlm_descs(cfg: ModelConfig) -> Dict:
    p = cfg.cross_attn_period
    n_groups = cfg.num_layers // p
    descs = _embed_descs(cfg)
    self_block = _block_descs(cfg, kind="attn")
    descs["group_selfs"] = stack_tree(stack_tree(self_block, p - 1), n_groups)
    descs["group_cross"] = stack_tree(_block_descs(cfg, kind="cross"), n_groups)
    return descs


def forward_vlm(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    last_only: bool = False,
    *,
    image_embeds: jax.Array,      # stub vision frontend output (B, Nimg, D)
    remat: str = "none",
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    B, S = tokens.shape
    decode = cache is not None
    positions = (
        jnp.broadcast_to(cache_index[None, None].astype(jnp.int32), (B, S))
        if decode
        else jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    )
    x = _embed(cfg, params, tokens)

    def group_body(carry, xs):
        h, _ = carry
        gs, gc, cs = xs
        h, ncs, _ = _scan_stack(
            cfg, gs, h, positions, kind="attn",
            cache=cs, cache_index=cache_index,
        )
        h, _, _ = _block_apply(
            cfg, gc, h, positions, kind="cross", cross_src=image_embeds
        )
        return (h, jnp.zeros((), F32)), ncs

    group_body = _maybe_remat(group_body, remat)
    xs = (
        params["group_selfs"], params["group_cross"],
        cache["group_selfs"] if decode else None,
    )
    (x, _), ncs = _scan(group_body, (x, jnp.zeros((), F32)), xs)
    new_cache = {"group_selfs": ncs} if decode else None
    return _logits(cfg, params, x, last_only), new_cache, jnp.zeros((), F32)


# --------------------------------------------------------------------------- #
# unified entry points                                                         #
# --------------------------------------------------------------------------- #
_FORWARD = {
    "dense": forward_dense,
    "moe": forward_dense,
    "ssm": forward_ssm,
    "hybrid": forward_hybrid,
    "encdec": forward_encdec,
    "vlm": forward_vlm,
}

_DESCS = {
    "dense": dense_descs,
    "moe": dense_descs,
    "ssm": ssm_descs_tree,
    "hybrid": hybrid_descs,
    "encdec": encdec_descs,
    "vlm": vlm_descs,
}


def param_descs(cfg: ModelConfig) -> Dict:
    return _DESCS[cfg.family](cfg)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *, extras=None, **kw):
    extras = extras or {}
    fwd = _FORWARD[cfg.family]
    if cfg.family == "encdec":
        return fwd(cfg, params, tokens, frames=extras["frames"], **kw)
    if cfg.family == "vlm":
        return fwd(cfg, params, tokens, image_embeds=extras["image_embeds"], **kw)
    return fwd(cfg, params, tokens, **kw)


def lm_loss(
    cfg: ModelConfig,
    logits: jax.Array,      # (B, S, Vp)
    labels: jax.Array,      # (B, S)
    aux: jax.Array,
) -> jax.Array:
    logits = logits.astype(F32)
    if cfg.vocab_padded != cfg.vocab_size:
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll) + aux


# --------------------------------------------------------------------------- #
# decode caches                                                                #
# --------------------------------------------------------------------------- #
def _attn_cache_desc(cfg: ModelConfig, batch: int, length: int) -> Dict[str, PDesc]:
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": PDesc((batch, length, nkv, hd), ("batch", "seq", "kv_heads", None), init="zeros"),
        "v": PDesc((batch, length, nkv, hd), ("batch", "seq", "kv_heads", None), init="zeros"),
    }


def _mla_cache_desc(cfg: ModelConfig, batch: int, length: int) -> Dict[str, PDesc]:
    m = cfg.mla
    return {
        "ckv": PDesc((batch, length, m.kv_lora_rank), ("batch", "seq", None), init="zeros"),
        "kpe": PDesc((batch, length, m.qk_rope_head_dim), ("batch", "seq", None), init="zeros"),
    }


def _ssm_cache_desc(cfg: ModelConfig, batch: int) -> Dict[str, PDesc]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return {
        "conv": PDesc((batch, s.d_conv - 1, conv_ch), ("batch", None, "ffn"), init="zeros"),
        "state": PDesc((batch, nh, s.head_dim, s.d_state), ("batch", "heads", None, None), init="zeros"),
    }


def cache_descs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Decode-cache descriptor tree matching the family's scan layout."""
    if cfg.family in ("dense", "moe"):
        plan = _dense_plan(cfg)
        mk = _mla_cache_desc if cfg.mla is not None else _attn_cache_desc
        if plan["kind"] == "flat":
            return {"layers": stack_tree(mk(cfg, batch, max_len), plan["layers"])}
        if plan["kind"] == "deepseek":
            return {
                "dense_layers": stack_tree(mk(cfg, batch, max_len), plan["dense"]),
                "moe_layers": stack_tree(mk(cfg, batch, max_len), plan["moe"]),
            }
        # gemma3: ring caches (window-sized) for locals, full for globals
        w = min(cfg.sliding_window, max_len)
        out = {
            "group_locals": stack_tree(
                stack_tree(_attn_cache_desc(cfg, batch, w), plan["locals"]), plan["groups"]
            ),
            "group_global": stack_tree(_attn_cache_desc(cfg, batch, max_len), plan["groups"]),
        }
        if plan["tail"]:
            out["tail_locals"] = stack_tree(_attn_cache_desc(cfg, batch, w), plan["tail"])
        return out
    if cfg.family == "ssm":
        return {"layers": stack_tree(_ssm_cache_desc(cfg, batch), cfg.num_layers)}
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        n_groups = cfg.num_layers // p
        tail = cfg.num_layers - n_groups * p
        out = {
            "shared_attn": stack_tree(_attn_cache_desc(cfg, batch, max_len), n_groups),
            "group_ssm": stack_tree(stack_tree(_ssm_cache_desc(cfg, batch), p), n_groups),
        }
        if tail:
            out["tail_ssm"] = stack_tree(_ssm_cache_desc(cfg, batch), tail)
        return out
    if cfg.family == "encdec":
        return {
            "decoder": stack_tree(_attn_cache_desc(cfg, batch, max_len), cfg.num_layers),
            "enc_out": PDesc(
                (batch, cfg.source_len, cfg.d_model), ("batch", None, "embed"), init="zeros"
            ),
        }
    if cfg.family == "vlm":
        p = cfg.cross_attn_period
        n_groups = cfg.num_layers // p
        return {
            "group_selfs": stack_tree(
                stack_tree(_attn_cache_desc(cfg, batch, max_len), p - 1), n_groups
            )
        }
    raise ValueError(cfg.family)


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    tokens: jax.Array,        # (B, 1)
    cache_index: jax.Array,   # scalar int32
    *,
    extras=None,
) -> Tuple[jax.Array, Dict]:
    extras = dict(extras or {})
    if cfg.family == "encdec":
        logits, new_cache, _ = forward_encdec(
            cfg, params, tokens,
            frames=extras.get("frames"),
            cache=cache, cache_index=cache_index,
            enc_out=cache.get("enc_out"),
        )
        return logits, new_cache
    logits, new_cache, _ = forward(
        cfg, params, tokens, extras=extras, cache=cache, cache_index=cache_index
    )
    return logits, new_cache
