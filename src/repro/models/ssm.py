"""Mamba-2 mixer via state-space duality (SSD), pure-JAX chunked form.

Train/prefill use the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is split into chunks; intra-chunk terms are dense matmuls (MXU
friendly — this is the part the Pallas kernel in kernels/ssd.py targets),
inter-chunk terms are a first-order recurrence over chunk states carried by
``lax.scan``. Decode keeps O(1) state per layer: a conv ring and the
(H, P, N) SSM state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .params import PDesc
from .scan_utils import _scan

F32 = jnp.float32


def ssm_descs(cfg: ModelConfig) -> Dict[str, PDesc]:
    s, d = cfg.ssm, cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    conv_ch = di + 2 * gn
    return {
        "w_z": PDesc((d, di), ("embed", "ffn")),
        "w_x": PDesc((d, di), ("embed", "ffn")),
        "w_B": PDesc((d, gn), ("embed", None)),
        "w_C": PDesc((d, gn), ("embed", None)),
        "w_dt": PDesc((d, nh), ("embed", None)),
        "conv_w": PDesc((s.d_conv, conv_ch), (None, "ffn")),
        "conv_b": PDesc((conv_ch,), ("ffn",), init="zeros"),
        "A_log": PDesc((nh,), (None,), init="zeros"),
        "D": PDesc((nh,), (None,), init="ones"),
        "dt_bias": PDesc((nh,), (None,), init="zeros"),
        "norm_w": PDesc((di,), ("ffn",), init="zeros"),
        "out_proj": PDesc((di, d), ("ffn", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4): unrolled adds fuse well
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _segsum(dA: jax.Array) -> jax.Array:
    """Lower-triangular pairwise decay logits within a chunk.
    dA: (..., L) -> (..., L, L) with out[i, j] = sum_{j < t <= i} dA[t]."""
    L = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    return jnp.where(j <= i, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)  (post-softplus)
    A: jax.Array,      # (H,)       (negative)
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xr = x.reshape(Bsz, nc, chunk, H, P)
    dtr = dt.reshape(Bsz, nc, chunk, H)
    Br = Bm.reshape(Bsz, nc, chunk, G, N)
    Cr = Cm.reshape(Bsz, nc, chunk, G, N)
    dA = dtr * A  # (B,nc,L,H)

    # intra-chunk (dense; the Pallas kernel computes exactly this term)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (B,nc,H,L,L)
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cr, Br)            # (B,nc,G,L,L)
    CB = jnp.repeat(CB, rep, axis=2)                          # (B,nc,H,L,L)
    gate = (CB * Lmat).astype(x.dtype)
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp", gate, dtr.astype(x.dtype), xr)

    # chunk states: decay-to-chunk-end weighted outer products
    dA_cum = jnp.cumsum(dA, axis=2)                           # (B,nc,L,H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)     # (B,nc,L,H)
    Bh = jnp.repeat(Br, rep, axis=3)                          # (B,nc,L,H,N)
    Bx = jnp.einsum(
        "bclhn,bclh,bclhp->bchpn",
        Bh.astype(F32),
        (dtr * decay_to_end).astype(F32),
        xr.astype(F32),
    )  # (B,nc,H,P,N)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))                # (B,nc,H)
    init = (
        jnp.zeros((Bsz, H, P, N), F32)
        if initial_state is None
        else initial_state.astype(F32)
    )

    def step(state, inp):
        bx_c, dec_c = inp
        new_state = state * dec_c[:, :, None, None] + bx_c
        return new_state, state  # emit the state seen by this chunk's queries

    # scan over chunks: move nc to leading axis
    bx_s = jnp.moveaxis(Bx, 1, 0)
    dec_s = jnp.moveaxis(chunk_decay, 1, 0)
    final_state, prev_states = _scan(step, init, (bx_s, dec_s), unrollable=False)
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (B,nc,H,P,N)

    # inter-chunk contribution: y += C_t · decayed prev chunk state
    in_decay = jnp.exp(dA_cum)                                # (B,nc,L,H)
    Ch = jnp.repeat(Cr, rep, axis=3)                          # (B,nc,L,H,N)
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", Ch.astype(F32), prev_states)
    y_inter = y_inter * in_decay[..., None]

    y = (y_diag.astype(F32) + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    x: jax.Array,     # (B, 1, H, P)
    dt: jax.Array,    # (B, 1, H)
    A: jax.Array,     # (H,)
    Bm: jax.Array,    # (B, 1, G, N)
    Cm: jax.Array,    # (B, 1, G, N)
    state: jax.Array,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    H = x.shape[2]
    G = Bm.shape[2]
    rep = H // G
    dA = jnp.exp(dt[:, 0, :] * A)                             # (B,H)
    Bh = jnp.repeat(Bm[:, 0], rep, axis=1)                    # (B,H,N)
    Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0].astype(F32), x[:, 0].astype(F32), Bh.astype(F32))
    new_state = state.astype(F32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(F32))
    return y[:, None].astype(x.dtype), new_state.astype(state.dtype)


def mamba2_mixer(
    p: Dict[str, jax.Array],
    x: jax.Array,                 # (B, S, D)
    cfg: ModelConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,  # {"conv": (B,K-1,C), "state": (B,H,P,N)}
    ssd_impl=None,                # optional kernel override (kernels/ops.py)
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    s = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    gn = s.n_groups * s.d_state

    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xs = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    Bm = jnp.einsum("bsd,dg->bsg", x, p["w_B"])
    Cm = jnp.einsum("bsd,dg->bsg", x, p["w_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(F32) + p["dt_bias"].astype(F32)
    )
    A = -jnp.exp(p["A_log"].astype(F32))

    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)              # (B,S,C)
    new_cache = None
    if cache is None:
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    else:
        k = s.d_conv
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,K-1+S,C)
        conv_out = jnp.einsum("bkc,kc->bc", window[:, -k:], p["conv_w"]) + p["conv_b"]
        xbc = jax.nn.silu(conv_out)[:, None]                  # (B,1,C)
        new_conv = window[:, -(k - 1) :]

    xs = xbc[..., :di].reshape(B, S, nh, s.head_dim)
    Bm = xbc[..., di : di + gn].reshape(B, S, s.n_groups, s.d_state)
    Cm = xbc[..., di + gn :].reshape(B, S, s.n_groups, s.d_state)

    if cache is None:
        run = ssd_impl or ssd_chunked
        y, _state = run(xs, dt.astype(x.dtype), A.astype(F32), Bm, Cm, s.chunk_size)
    else:
        y, new_state = ssd_decode_step(xs, dt.astype(F32), A, Bm, Cm, cache["state"])
        new_cache = {"conv": new_conv, "state": new_state}

    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm then down-projection (Mamba-2 block epilogue)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype) * (
        1.0 + p["norm_w"].astype(x.dtype)
    )
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"]), new_cache
