"""Model configuration covering all 10 assigned architecture families.

One dataclass family; unused sub-configs are None. Exact dimensions live in
``repro.configs.<arch>`` (one file per assigned architecture).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    num_shared: int = 0              # always-on shared experts (DeepSeek)
    first_k_dense: int = 0           # leading dense (non-MoE) layers
    dense_d_ff: int = 0              # FFN size of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0             # 0 => full-rank Q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads
    # attention details
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # local-attention window size
    global_period: int = 0           # gemma3: every Nth layer is global (rest local)
    activation: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every N SSM layers
    hybrid_attn_period: int = 0
    # encoder-decoder (seamless): encoder depth; num_layers = decoder depth
    encoder_layers: int = 0
    source_len: int = 1024           # stubbed modality frontend: frame count
    # vlm (llama-3.2-vision): one gated cross-attn layer every N layers
    cross_attn_period: int = 0
    num_image_tokens: int = 1024
    # notes recorded per-config (vocab padding, interpretation decisions)
    notes: Tuple[str, ...] = ()

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def vocab_padded(self) -> int:
        """Pad vocab to a multiple of 2048 (16 model shards x 128 MXU lanes)."""
        m = 2048
        return ((self.vocab_size + m - 1) // m) * m

    def layer_kinds(self) -> List[str]:
        """Per-layer mixer kind, for heterogeneous stacks."""
        kinds: List[str] = []
        for i in range(self.num_layers):
            if self.family in ("ssm", "hybrid"):
                kinds.append("ssm")
            elif self.global_period and (i + 1) % self.global_period != 0:
                kinds.append("local_attn")
            else:
                kinds.append("attn")
        return kinds

    def supports_long_context(self) -> bool:
        """True iff a 500k-token decode is architecturally sub-quadratic:
        SSM/hybrid (O(1) state) or sliding-window-dominant stacks."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec included)

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_padded * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        total += d  # final norm

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * (m.q_lora_rank or 0)
                q_in = m.q_lora_rank or d
                p += q_in * n_q * qk_dim
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                p += n_q * m.v_head_dim * d
                return p
            return d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gated MLP

        def ssm_params() -> int:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            p = d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
            p += s.d_conv * (di + 2 * s.n_groups * s.d_state)   # conv
            p += nh * 2 + di                                     # A, D, dt_bias-ish
            p += di * d                                          # out_proj
            return p

        for i, kind in enumerate(self.layer_kinds()):
            total += 2 * d  # norms
            if kind == "ssm":
                total += ssm_params()
            else:
                total += attn_params()
                if self.moe is not None:
                    mo = self.moe
                    if i < mo.first_k_dense:
                        total += mlp_params(mo.dense_d_ff)
                    else:
                        total += d * mo.num_experts  # router
                        total += mo.num_experts * 3 * d * mo.d_expert
                        total += mo.num_shared * 3 * d * mo.d_expert
                else:
                    total += mlp_params(self.d_ff)
        if self.family in ("ssm",):
            pass
        if self.hybrid_attn_period:
            total += attn_params() + mlp_params(self.d_ff) + 2 * d  # shared block
        if self.encoder_layers:
            total += self.encoder_layers * (2 * d + attn_params() + mlp_params(self.d_ff))
            total += self.num_layers * (d + attn_params())  # decoder cross-attn
        if self.cross_attn_period:
            n_cross = self.num_layers // self.cross_attn_period
            total += n_cross * (attn_params() + 2 * d + 2)
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
