"""Deterministic, checkpointable synthetic LM data pipeline.

``batch_at(step)`` is a pure function of (seed, step): any replay after a
rollback reproduces the exact byte-identical batch, which is what makes the
end-to-end determinism test (failure run == failure-free run) meaningful.
The cursor is a libDSE StateObject so batch lineage participates in the
recovery dependency graph: the trainer consumes the cursor's header each
step, giving the data->trainer edge from DESIGN.md §2.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore


class SyntheticLMData:
    """Zipf-ish token stream with a little structure (ngram repetition) so
    losses actually decrease during the example runs."""

    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        # zipf-like marginal over the vocab
        u = rng.random((self.global_batch, self.seq_len + 1))
        toks = np.floor((self.vocab_size - 1) * u ** 3.0).astype(np.int32)
        # inject determinism-friendly structure: repeat the first half-gram
        half = (self.seq_len + 1) // 2
        toks[:, half : 2 * half] = toks[:, :half]
        return toks


class DataPipelineStateObject(StateObject):
    """Checkpointable stream cursor. ``next_batch`` is an action producing
    the batch AND a header the trainer consumes (lineage edge)."""

    def __init__(self, root: Path, data: SyntheticLMData) -> None:
        super().__init__()
        self.store = VersionStore(root)
        self.data = data
        self.cursor = 0
        self._mu = threading.Lock()

    # -- persistence ---------------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        with self._mu:
            payload = json.dumps({"cursor": self.cursor}).encode()

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        with self._mu:
            self.cursor = json.loads(payload.decode())["cursor"]
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        with self._mu:
            self.cursor = 0

    # -- service API -----------------------------------------------------------
    def next_batch(self, header: Optional[Header] = None):
        """Returns (step, tokens, header) or None if sender rolled back."""
        if not self.StartAction(header):
            return None
        with self._mu:
            step = self.cursor
            self.cursor += 1
        tokens = self.data.batch_at(step)
        return step, tokens, self.EndAction()

    def peek_cursor(self) -> int:
        with self._mu:
            return self.cursor

    def seek(self, step: int, header: Optional[Header] = None):
        """Reset the cursor (used when the trainer resumes from an older
        checkpoint than the cursor — control flow is persisted state)."""
        if not self.StartAction(header):
            return None
        with self._mu:
            self.cursor = step
        return self.EndAction()
