from .pipeline import DataPipelineStateObject, SyntheticLMData

__all__ = ["DataPipelineStateObject", "SyntheticLMData"]
