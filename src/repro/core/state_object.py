"""StateObject abstraction (paper §3.1, Tables 1 & 2).

Developers implement the four persistence methods (``Persist``, ``Restore``,
``Prune``, ``ListVersions``); the runtime-provided methods (``Connect``,
``StartAction``, ``EndAction``, ``Detach``, ``Merge``, ``Refresh``) are
concrete here and delegate to the attached :class:`~repro.core.runtime.DSERuntime`.
Method names deliberately mirror the paper's API.
"""
from __future__ import annotations

import abc
import os
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .runtime import DSEConfig, DSERuntime
    from .sthread import SThread
    from .ids import Header


class StateObject(abc.ABC):
    """A stateful, message-passing, fail-restart entity (paper §3)."""

    def __init__(self) -> None:
        self._runtime: Optional["DSERuntime"] = None

    # ------------------------------------------------------------------ #
    # Developer-implemented persistence backend (paper Table 1)          #
    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        """Persist current state + ``metadata`` as ``version``; invoke
        ``callback`` once durable. May return before completion (async),
        but MUST capture a consistent snapshot before returning — the
        runtime guarantees no action interleaves with this call."""

    @abc.abstractmethod
    def Restore(self, version: int) -> bytes:
        """Recover (or roll back) to ``version``; return its metadata."""

    def Prune(self, version: int) -> None:  # optional
        """Versions *preceding* ``version`` may be discarded; ``version``
        itself must stay listable — it is the durable floor anchor the
        fragment-GC'd resend path ships to a recovering coordinator
        (DESIGN.md §11)."""

    @abc.abstractmethod
    def ListVersions(self) -> List[Tuple[int, bytes]]:
        """All unpruned durable versions with their metadata."""

    # ------------------------------------------------------------------ #
    # Runtime-provided API (paper Table 2)                               #
    # ------------------------------------------------------------------ #
    def Connect(self, config: "DSEConfig") -> None:
        from .runtime import DSERuntime

        if self._runtime is not None:
            raise RuntimeError("Connect must be invoked exactly once")
        kind = getattr(config, "runtime", "dse")
        if kind == "durable":
            # lazy import: repro.durable depends on repro.core, not vice versa
            from ..durable.runtime import DurableRuntime as runtime_cls
        elif kind == "dse":
            runtime_cls = DSERuntime
        else:
            raise ValueError(f"unknown runtime {kind!r} (expected 'dse' or 'durable')")
        self._runtime = runtime_cls(self, config)
        # stores exist before the clock does (service constructors run
        # first): bind every VersionStore to the runtime's injected clock
        for attr in vars(self).values():
            if isinstance(attr, VersionStore):
                attr.bind_clock(self._runtime.clock)
        self._runtime.connect()

    def StartAction(self, header: Optional["Header"] = None) -> bool:
        return self.runtime.start_action(header)

    def EndAction(self) -> "Header":
        return self.runtime.end_action()

    def Detach(self) -> "SThread":
        return self.runtime.detach()

    def Merge(self, sthread: "SThread") -> bool:
        return self.runtime.merge(sthread)

    def Refresh(self) -> None:
        self.runtime.refresh()

    def spawn_io(self, fn: Callable[[], None], name: str = "persist-io") -> None:
        """Run ``fn`` on an independent thread of control via the runtime's
        injected clock — a real daemon thread in production, a scheduled
        task under deterministic simulation (DESIGN.md §8). Persistence
        backends use this for their async IO instead of raw
        ``threading.Thread`` so ``Persist`` completion is simulatable."""
        if self._runtime is not None:
            self._runtime.clock.spawn(fn, name=f"{self._runtime.so_id}:{name}")
        else:
            threading.Thread(target=fn, name=name, daemon=True).start()

    def wait_durable(self, timeout: Optional[float] = None) -> bool:
        """Convenience: must be called *inside* an action. Blocks until the
        action's state (and everything it observed) is non-speculative, then
        re-enters an action. Returns False if the state was rolled back.
        This is how non-speculative baselines emulate synchronous persistence
        (durable-execution semantics) on top of libDSE."""
        t = self.Detach()
        try:
            t.Barrier(timeout=timeout)
        except Exception:
            return False
        return self.Merge(t)

    @property
    def runtime(self) -> "DSERuntime":
        if self._runtime is None:
            raise RuntimeError("StateObject is not Connected")
        return self._runtime

    @property
    def connected(self) -> bool:
        return self._runtime is not None


class VersionStore:
    """Durable multi-version blob store with an in-memory fast tier.

    A reusable persistence backend for services: each version is an opaque
    ``bytes`` snapshot written atomically (tmp + rename => a crashed writer
    never yields a listable version) plus metadata sidecar. The in-memory
    tier makes rollback cheap (paper §3.1 encourages built-in
    multiversioning); the disk tier is the durable point of truth used by a
    restarted incarnation.
    """

    def __init__(
        self,
        root: Path,
        keep_in_memory: int = 8,
        simulate_io_ms: float = 0.0,
        clock=None,
    ) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._mem: Dict[int, Tuple[bytes, bytes]] = {}
        self._mem_order: List[int] = []
        self._keep = keep_in_memory
        self._lock = threading.Lock()
        self._simulate_io_ms = simulate_io_ms
        self._clock = clock  # None => real time.sleep for simulated IO delay
        self._poisoned = False

    def bind_clock(self, clock) -> None:
        """Late-bind an injected clock (DESIGN.md §8). Services build their
        stores in their constructors, before ``Connect`` delivers the
        runtime's clock — without the rebind, ``simulate_io_ms`` would burn
        real wall time (and zero virtual time) under simulation."""
        if self._clock is None:
            self._clock = clock

    # -- write path -----------------------------------------------------
    def poison(self) -> None:
        """Simulate process death: all subsequent writes fail (a crashed
        incarnation must not keep mutating durable state, paper §5.1)."""
        self._poisoned = True

    def write(self, version: int, payload: bytes, metadata: bytes) -> None:
        """Durably write one version (synchronous; callers wrap in executor)."""
        if self._poisoned:
            raise RuntimeError("VersionStore poisoned (incarnation crashed)")
        if self._simulate_io_ms > 0:
            if self._clock is not None:
                self._clock.sleep(self._simulate_io_ms / 1e3)
            else:
                import time

                time.sleep(self._simulate_io_ms / 1e3)
        tmp = self.root / f".v{version}.tmp"
        final = self.root / f"v{version}.blob"
        with open(tmp, "wb") as f:
            f.write(len(metadata).to_bytes(8, "little"))
            f.write(metadata)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        if self._poisoned:
            # crashed incarnation must not PUBLISH: an in-flight write that
            # survived the entry check could otherwise clobber the restarted
            # incarnation's same-numbered version with rolled-back state.
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise RuntimeError("VersionStore poisoned (incarnation crashed)")
        os.replace(tmp, final)
        with self._lock:
            self._mem[version] = (payload, metadata)
            self._mem_order.append(version)
            while len(self._mem_order) > self._keep:
                self._mem.pop(self._mem_order.pop(0), None)

    def put_memory(self, version: int, payload: bytes, metadata: bytes) -> None:
        """Stage a version in the memory tier only (lost on crash)."""
        with self._lock:
            self._mem[version] = (payload, metadata)
            self._mem_order.append(version)
            while len(self._mem_order) > self._keep:
                self._mem.pop(self._mem_order.pop(0), None)

    # -- read path ------------------------------------------------------
    def read(self, version: int) -> Tuple[bytes, bytes]:
        with self._lock:
            if version in self._mem:
                return self._mem[version]
        final = self.root / f"v{version}.blob"
        with open(final, "rb") as f:
            mlen = int.from_bytes(f.read(8), "little")
            metadata = f.read(mlen)
            payload = f.read()
        return payload, metadata

    def list_versions(self) -> List[Tuple[int, bytes]]:
        out: List[Tuple[int, bytes]] = []
        # numeric order, not lexical (v10 after v9, not between v1 and v2)
        for p in sorted(self.root.glob("v*.blob"), key=lambda p: int(p.stem[1:])):
            version = int(p.stem[1:])
            try:
                with open(p, "rb") as f:
                    mlen = int.from_bytes(f.read(8), "little")
                    metadata = f.read(mlen)
            except FileNotFoundError:
                continue  # pruned concurrently (in-flight Refresh of a dying incarnation)
            out.append((version, metadata))
        return out

    def prune(self, version: int) -> None:
        for p in list(self.root.glob("v*.blob")):
            if int(p.stem[1:]) < version:
                try:
                    p.unlink()
                except OSError:
                    pass
        with self._lock:
            for v in [v for v in self._mem if v < version]:
                self._mem.pop(v, None)
            self._mem_order = [v for v in self._mem_order if v in self._mem]

    def drop_memory(self) -> None:
        """Simulate crash: lose the in-memory tier."""
        with self._lock:
            self._mem.clear()
            self._mem_order.clear()
