"""libDSE core — the paper's contribution: distributed speculative execution
via message-passing StateObjects, atomic actions, sthreads, speculation
barriers, and a DPR-derived recovery protocol with a stateless coordinator.
"""
from .clock import Clock, REAL_CLOCK, RealClock
from .ids import DecisionIndex, Header, PersistReport, RollbackDecision, Vertex
from .epoch import EpochRWLock
from .graph import DependencyGraph
from .state_object import StateObject, VersionStore
from .runtime import CrashedError, DSEConfig, DSERuntime
from .sthread import DelayMessage, RolledBackError, SThread
from .coordinator import ConnectResponse, Coordinator, PollResponse
from .cluster import LocalCluster

__all__ = [
    "Clock",
    "REAL_CLOCK",
    "RealClock",
    "DecisionIndex",
    "Header",
    "PersistReport",
    "RollbackDecision",
    "Vertex",
    "EpochRWLock",
    "DependencyGraph",
    "StateObject",
    "VersionStore",
    "CrashedError",
    "DSEConfig",
    "DSERuntime",
    "DelayMessage",
    "RolledBackError",
    "SThread",
    "ConnectResponse",
    "Coordinator",
    "PollResponse",
    "LocalCluster",
]
