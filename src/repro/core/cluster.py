"""LocalCluster — in-process deployment + failure-injection harness.

Plays the role Kubernetes plays in the paper's deployment (§5.1): it hosts
StateObject incarnations, drives the background protocol (``Refresh``),
detects "down" services (here: explicit ``kill``), replaces them with fresh
incarnations, and reconnects them to the coordinator — which is exactly the
signal libDSE uses to trigger cluster-level recovery.

Transport note (DESIGN.md §2): services in this repo call each other
in-process, passing :class:`~repro.core.ids.Header` objects where the paper
passes gRPC HTTP headers. The protocol is transport-agnostic; ``call`` below
provides the retry-on-delay semantics a gRPC interceptor would.
"""
from __future__ import annotations

import shutil
import threading
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional

from .clock import Clock, REAL_CLOCK, SpawnHandle
from .coordinator import Coordinator
from .runtime import CrashedError, DSEConfig
from .sthread import DelayMessage
from .state_object import StateObject


class LocalCluster:
    def __init__(
        self,
        root: Path,
        *,
        group_commit_interval: float = 0.010,
        refresh_interval: Optional[float] = 0.002,
        strict_commit_ordering: bool = False,
        persist_jitter: float = 0.0,
        barrier_poll_interval: float = 0.002,
        runtime: str = "dse",
        clock: Clock = REAL_CLOCK,
        checkpoint_records: Optional[int] = 256,
        checkpoint_bytes: int = 1 << 20,
    ) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.clock = clock
        #: coordinator durable-store knobs (repro.store, DESIGN.md §11);
        #: checkpoint_records=None disables snapshot compaction entirely
        self._store_kw = dict(
            checkpoint_records=checkpoint_records, checkpoint_bytes=checkpoint_bytes
        )
        self.coordinator = self._make_coordinator()
        # ``runtime`` selects the execution engine every member Connects
        # with: "dse" (speculative) or "durable" (synchronous baseline);
        # per-SO ``add(..., runtime=...)`` overrides win.
        self._defaults = dict(
            group_commit_interval=group_commit_interval,
            strict_commit_ordering=strict_commit_ordering,
            persist_jitter=persist_jitter,
            barrier_poll_interval=barrier_poll_interval,
            runtime=runtime,
            clock=clock,
        )
        # Held across restart_coordinator's rebuild, which can acquire
        # coordinator/bus locks => must be clock-sourced (see core/clock.py).
        self._lock = clock.rlock()
        self._sos: Dict[str, StateObject] = {}
        self._factories: Dict[str, Callable[[], StateObject]] = {}
        self._overrides: Dict[str, dict] = {}
        self._stop = clock.event()
        self._refresher: Optional[SpawnHandle] = None
        if refresh_interval is not None:
            self._refresher = clock.spawn(
                lambda: self._refresh_loop(refresh_interval), name="dse-refresher"
            )

    # ------------------------------------------------------------------ #
    # deployment hooks (overridden by repro.net.NetCluster)              #
    # ------------------------------------------------------------------ #
    def _make_coordinator(self):
        """Build (or rebuild, after restart_coordinator) the coordinator."""
        return Coordinator(
            self.root / "coordinator.jsonl", clock=self.clock, **self._store_kw
        )

    def _coordinator_handle(self, so_id: str):
        """The coordinator handle a StateObject's runtime talks to. The base
        cluster hands out the coordinator object itself (direct in-process
        calls); NetCluster hands out a transport-backed proxy."""
        return self.coordinator

    # ------------------------------------------------------------------ #
    # membership                                                         #
    # ------------------------------------------------------------------ #
    def add(self, so_id: str, factory: Callable[[], StateObject], **overrides) -> StateObject:
        """Deploy a StateObject; ``factory`` is reused to build replacement
        incarnations after ``kill``."""
        so = factory()
        cfg = DSEConfig(
            so_id=so_id,
            coordinator=self._coordinator_handle(so_id),
            **{**self._defaults, **overrides},
        )
        so.Connect(cfg)
        with self._lock:
            self._sos[so_id] = so
            self._factories[so_id] = factory
            self._overrides[so_id] = overrides
        return so

    def get(self, so_id: str) -> StateObject:
        with self._lock:
            return self._sos[so_id]

    def members(self) -> List[str]:
        with self._lock:
            return list(self._sos.keys())

    # ------------------------------------------------------------------ #
    # failure injection                                                  #
    # ------------------------------------------------------------------ #
    def kill(self, so_id: str, *, restart: bool = True) -> Optional[StateObject]:
        """Crash the current incarnation (losing all volatile state) and, by
        default, immediately restart it — which triggers rollback recovery
        when the new incarnation re-Connects."""
        with self._lock:
            old = self._sos[so_id]
        old.runtime.mark_dead()
        crash = getattr(old, "on_crash", None)
        if callable(crash):
            crash()  # drop in-memory tiers / poison the store
        if not restart:
            with self._lock:
                self._sos.pop(so_id, None)
            return None
        return self._restart(so_id)

    def _restart(self, so_id: str) -> StateObject:
        so = self._factories[so_id]()
        cfg = DSEConfig(
            so_id=so_id,
            coordinator=self._coordinator_handle(so_id),
            **{**self._defaults, **self._overrides.get(so_id, {})},
        )
        so.Connect(cfg)
        with self._lock:
            self._sos[so_id] = so
        return so

    def checkpoint(self) -> None:
        """Snapshot-compact the coordinator's durable store (every shard, in
        sharded deployments) — the operator-facing arm of DESIGN.md §11;
        the size-threshold auto-trigger does the same thing unprompted."""
        self.coordinator.checkpoint()

    def restart_coordinator(self) -> None:
        """Simulate coordinator failure + recovery: a new coordinator replays
        the durable log and collects fragments from every participant."""
        with self._lock:
            old = self.coordinator
            self.coordinator = self._make_coordinator()
            for so in self._sos.values():
                so.runtime.coordinator = self._coordinator_handle(so.runtime.so_id)
        old.close()

    # ------------------------------------------------------------------ #
    # protocol driving                                                   #
    # ------------------------------------------------------------------ #
    def refresh_all(self) -> None:
        """One synchronous Refresh round (deterministic driving for tests)."""
        with self._lock:
            sos = list(self._sos.values())
        for so in sos:
            try:
                so.Refresh()
            except (CrashedError, TimeoutError):
                # TimeoutError: the transport fabric dropped this round's
                # coordinator RPCs (loss / partition); retry next round.
                pass

    def _refresh_loop(self, interval: float) -> None:
        while not self._stop.is_set():
            try:
                self.refresh_all()
            except Exception:
                # The background refresher must survive anything a faulty
                # fabric or a mid-restart incarnation throws; a dead refresher
                # silently freezes the boundary and undelivers decisions.
                # (Manual refresh_all still surfaces unexpected errors.)
                pass
            self._stop.wait(interval)

    # ------------------------------------------------------------------ #
    # transport helper                                                   #
    # ------------------------------------------------------------------ #
    @staticmethod
    def call(
        fn: Callable,
        *args,
        retries: int = 200,
        backoff: float = 0.002,
        clock: Clock = REAL_CLOCK,
        **kwargs,
    ):
        """Invoke a service handler with retry-on-delay semantics (what the
        gRPC integration layer does in the paper when a message arrives from
        a future failure epoch, Def 4.3)."""
        for _ in range(retries):
            try:
                return fn(*args, **kwargs)
            except DelayMessage:
                clock.sleep(backoff)
        raise TimeoutError("message delayed past retry budget")

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def shutdown(self) -> None:
        self._stop.set()
        if self._refresher is not None:
            self._refresher.join(timeout=2.0)
        # Persist outstanding state so clean shutdown is not a failure
        # (paper §5.1: no explicit disconnect is needed if state is durable),
        # then DRAIN the async persist IO so directory teardown cannot race
        # in-flight writes.
        with self._lock:
            sos = list(self._sos.values())
        labels = []
        for so in sos:
            try:
                labels.append((so, so.runtime.maybe_persist(force=True)))
            except Exception:
                labels.append((so, None))
        deadline = self.clock.now() + 3.0
        for so, label in labels:
            if label is None:
                continue
            while self.clock.now() < deadline:
                try:
                    if so.runtime.stats()["committed"] >= label:
                        break
                except Exception:
                    break
                self.clock.sleep(0.002)
        self.coordinator.close()

    def wipe(self) -> None:
        shutil.rmtree(self.root, ignore_errors=True)

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
