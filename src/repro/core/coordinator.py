"""Stateless libDSE coordinator (paper §4.3).

The coordinator's *point of truth is the collective persisted state of the
participants*: dependency-graph fragments are persisted inside each
StateObject (via the ``metadata`` argument of ``Persist``) and reported
asynchronously, so the coordinator holds only a (possibly stale) **view**
of the real graph. Nothing is persisted by the coordinator on the
failure-free path — its log records only *membership changes* and
*rollback decisions* (which must be durable before release, as they embody
cluster consensus).

Soundness of the stale view (paper §4.3, Finding Boundaries): the
persistent part of the graph is immutable — future operations add vertices
but never change past dependencies — so any recoverable boundary found on
the coordinator's present view remains recoverable on every later view.
Rollback targets computed on the stale view are *conservative*: a persisted
vertex the coordinator has not yet seen is above its owner's target and is
therefore rolled back (paper §5.3 acknowledges this over-rollback; the
StateObject-side skip mitigation in ``DSERuntime._apply_decision`` recovers
the common case).

Coordinator recovery (paper §4.3): a restarted coordinator replays its
durable store to recover membership + past decisions, then asks every
participant to resend its locally persisted graph fragments; it refuses to
answer boundary queries (returns ``None``) until every participant has
responded, which guarantees a view at least as fresh as the pre-failure one.

Bounded recovery (DESIGN.md §11): the durable store is a
:class:`~repro.store.CompactingLog` — ``checkpoint()`` folds the current
durable cut (graph at the exposure floor, non-retired decisions, world
counter, per-SO flush seqs) into a binary snapshot and rotates the JSONL
log to a suffix, so replay is O(live state + suffix) instead of O(every
record since the cluster was born), and fully-superseded decisions (whose
lost windows every exposure floor has passed) retire from the durable cut,
the in-memory lists, and every future ConnectResponse.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .clock import Clock, REAL_CLOCK
from .graph import DependencyGraph
from .ids import DecisionIndex, PersistReport, RollbackDecision, Vertex
from ..store import CompactingLog, CoordinatorSnapshot, decode_snapshot, encode_snapshot


@dataclass
class ConnectResponse:
    world: int
    decisions: List[RollbackDecision]
    boundary: Optional[Dict[str, int]]
    #: version the connecting incarnation must Restore to; None => fresh start
    restore_to: Optional[int] = None
    #: generation of ``boundary`` — quote back via ``poll(known_boundary_seq=)``
    boundary_seq: int = -1


@dataclass
class PollResponse:
    decisions: List[RollbackDecision] = field(default_factory=list)
    #: None when the view is incomplete (recovery) OR when the caller's
    #: ``known_boundary_seq`` is current — nothing moved, no dict shipped.
    boundary: Optional[Dict[str, int]] = None
    resend_fragments: bool = False
    #: generation counter for delta polls; -1 from pre-seq coordinators
    boundary_seq: int = -1


class Coordinator:
    """Embodies cluster consensus as the (singleton) leader (paper §4.2)."""

    def __init__(
        self,
        log_path: Path,
        recovery_timeout: float = 30.0,
        clock: Clock = REAL_CLOCK,
        *,
        checkpoint_records: Optional[int] = 256,
        checkpoint_bytes: int = 1 << 20,
    ) -> None:
        self.clock = clock
        self._lock = clock.rlock()
        self._recovered_cv = clock.condition(self._lock)
        #: durable store: snapshot + JSONL suffix; the thresholds arm the
        #: auto-compaction trigger (None disables checkpoints entirely).
        self._log = CompactingLog(
            log_path,
            checkpoint_records=checkpoint_records,
            checkpoint_bytes=checkpoint_bytes,
        )
        self._graph = DependencyGraph()
        self._members: Set[str] = set()
        #: decisions sorted by fsn, with a parallel fsn list (bisect) and a
        #: compacted per-SO invalidation index (O(log n) classification)
        self._decisions: List[RollbackDecision] = []
        self._decision_fsns: List[int] = []
        self._dindex = DecisionIndex()
        self._fsn = 0
        #: decisions with fsn <= this were retired by the compactor: every
        #: exposure floor passed their lost windows, so nothing they could
        #: invalidate can ever be reported, resent, or adopted again — and
        #: every live (or future) incarnation's world is already past them.
        self._retired_upto = 0
        #: the exposure floor of the last installed (or recovered) snapshot —
        #: the fallback cut for a checkpoint taken before a live floor exists
        self._snapshot_floor: Dict[str, int] = {}
        self.checkpoints = 0
        self._recovery_timeout = recovery_timeout
        #: so_id -> set of (world, seq) report flushes already processed:
        #: drops the duplicate when a transport retry of a timed-out report
        #: RPC lands after the runtime's requeue path already resent it.
        #: Part of the snapshot's durable cut, so a snapshot-recovered
        #: coordinator still single-counts a pre-crash flush's retry (a
        #: suffix-era duplicate merely re-ingests, which is idempotent).
        self._report_seen: Dict[str, Set[Tuple[int, int]]] = {}
        self.dup_reports_dropped = 0

        # Recover the durable cut, then replay the suffix: membership +
        # decisions (suffix decisions must also re-apply their truncations,
        # because the snapshot's graph predates them).
        snap_blob, suffix = self._log.replay()
        restored = snap_blob is not None
        if restored:
            snap = decode_snapshot(snap_blob)
            self._fsn = snap.fsn
            self._retired_upto = snap.retired_upto
            self._members = set(snap.members)
            for d in snap.decisions:
                self._note_decision(d)
            self._graph.restore_state(snap.graph)
            self._snapshot_floor = dict(snap.floor)
            self._report_seen = {so: set(pairs) for so, pairs in snap.report_seen.items()}
        for rec in suffix:
            if rec.get("type") == "member":
                self._members.add(rec["so_id"])
            elif rec.get("type") == "decision":
                d = RollbackDecision.from_json(rec)
                self._note_decision(d)
                if restored:
                    for so, t in d.targets.items():
                        self._graph.truncate(so, t)
        # If members existed, this is a restarted coordinator: the graph view
        # must be rebuilt from participants before boundaries can be served
        # (the snapshot is the warm O(live) base; resends are the freshness
        # guarantee and, post-GC, ship only the O(live) suffix).
        self._awaiting: Set[str] = set(self._members)
        #: lock-free mirror of ``bool(self._awaiting)`` (read by the sharded
        #: DecisionBus without taking this coordinator's lock).
        self.is_awaiting = bool(self._awaiting)
        for so in self._members:
            self._graph.add_member(so)

        self._dirty = True
        self._boundary_cache: Dict[str, int] = {}
        #: generation of ``_boundary_cache``; bumped on every actual change so
        #: steady-state polls are answered "nothing moved" without a rebuild
        self._boundary_seq = 0
        #: last graph change-counter folded into the cache
        self._graph_version = -1

    # ------------------------------------------------------------------ #
    # helpers                                                            #
    # ------------------------------------------------------------------ #
    def _note_decision(self, d: RollbackDecision) -> None:
        """Record a decision in the fsn-sorted list + compacted index
        (call with self._lock held, or from __init__)."""
        i = bisect.bisect_left(self._decision_fsns, d.fsn)
        if i < len(self._decision_fsns) and self._decision_fsns[i] == d.fsn:
            return  # replayed duplicate
        self._decision_fsns.insert(i, d.fsn)
        self._decisions.insert(i, d)
        self._dindex.add(d)
        self._fsn = max(self._fsn, d.fsn)

    def _decisions_after(self, known_world: int) -> List[RollbackDecision]:
        """Decisions with fsn > known_world — O(log n + delta), not a scan
        (call with self._lock held)."""
        i = bisect.bisect_right(self._decision_fsns, known_world)
        return self._decisions[i:]

    def _ingest(self, reports: Iterable[PersistReport]) -> None:
        """Incorporate persisted-vertex reports, dropping any vertex an
        existing decision has already invalidated (stale blobs / in-flight
        reports from a pre-rollback incarnation)."""
        for r in reports:
            if self._dindex.invalidates(r.vertex):
                continue
            deps = [(d.so_id, d.version) for d in r.deps if d.so_id != r.vertex.so_id]
            self._graph.report_persistent(r.vertex.so_id, r.vertex.version, deps)
            self._dirty = True

    def _boundary_locked(
        self, known_seq: Optional[int] = None
    ) -> Tuple[Optional[Dict[str, int]], int]:
        """(boundary, seq) — None while the view is incomplete (coordinator
        recovery in progress), or when the caller already holds generation
        ``known_seq`` (delta poll: nothing moved, don't even copy the dict).
        Call with self._lock held."""
        if self._awaiting:
            return None, self._boundary_seq
        if self._dirty:
            self._dirty = False
            ver = self._graph.boundary_version()
            if ver != self._graph_version:
                ver, bound = self._graph.incremental_boundary()
                self._graph_version = ver
                if bound != self._boundary_cache:
                    self._boundary_cache = bound
                    self._boundary_seq += 1
                    # Vertices inside the boundary are immortal: prune their
                    # dep lists, keeping only the floor watermark (memory
                    # bound).
                    for so, b in bound.items():
                        self._graph.prune(so, b)
        # Auto-compaction rides the boundary recompute: the floor is fresh
        # here, the lock is held, and log growth (decisions/members) always
        # marks the boundary dirty, so the trigger is visited promptly.
        if self._log.should_checkpoint():
            self._checkpoint_locked(dict(self._boundary_cache))
        if known_seq == self._boundary_seq:
            return None, self._boundary_seq
        return dict(self._boundary_cache), self._boundary_seq

    # Overridden by CoordinatorShard to defer to the DecisionBus (and then
    # called WITHOUT self._lock, like the other merged-view hooks below).
    def _boundary_with_seq(
        self, known_seq: Optional[int] = None
    ) -> Tuple[Optional[Dict[str, int]], int]:
        with self._lock:
            return self._boundary_locked(known_seq)

    def _boundary(self) -> Optional[Dict[str, int]]:
        return self._boundary_with_seq()[0]

    def _awaiting_changed(self) -> None:
        self.is_awaiting = bool(self._awaiting)

    # Hooks a sharded deployment overrides to merge per-shard state into the
    # single global view (repro.net.sharded.CoordinatorShard). They must be
    # called WITHOUT self._lock held: the sharded variants reach across
    # shards, and holding one shard's lock while acquiring another's would
    # deadlock under concurrent failures.
    def _world(self) -> int:
        with self._lock:
            return self._fsn

    def _all_decisions(self) -> List[RollbackDecision]:
        with self._lock:
            return list(self._decisions)

    def _decide(self, so_id: str, surviving: int) -> RollbackDecision:
        """Compute, durably log, and apply a rollback decision."""
        with self._lock:
            # Top persisted label per SO BEFORE any truncation: every vertex
            # this decision can ever invalidate lies in (target, lost[so]] —
            # the retirement witness the snapshot compactor checks floors
            # against (DESIGN.md §11).
            tops = self._graph.committed_watermarks()
            # Remove the failed SO's lost vertices, then find the greatest
            # closure of what remains (iteratively removing dangling refs).
            self._graph.truncate(so_id, surviving)
            targets = self._graph.rollback_targets(so_id, surviving)
            fsn = self._fsn + 1
            decision = RollbackDecision(
                fsn=fsn,
                failed=so_id,
                targets=targets,
                lost={so: tops.get(so, t) for so, t in targets.items()},
            )
            # Consensus step: the decision must be durable before any
            # participant can observe it (paper §4.3, Orchestrating Rollback).
            self._log.append({"type": "decision", **decision.to_json()})
            self._note_decision(decision)
            for so, t in targets.items():
                self._graph.truncate(so, t)
            self._dirty = True
            return decision

    def _wait_recovered(self, exclude: Set[str]) -> None:
        deadline = self.clock.now() + self._recovery_timeout
        while self._awaiting - exclude:
            remaining = deadline - self.clock.now()
            if remaining <= 0:
                raise TimeoutError(
                    f"coordinator recovery stalled; awaiting fragments from "
                    f"{sorted(self._awaiting - exclude)}"
                )
            self._recovered_cv.wait(timeout=min(remaining, 0.05))

    # ------------------------------------------------------------------ #
    # participant API                                                    #
    # ------------------------------------------------------------------ #
    def connect(self, so_id: str, fragments: Sequence[PersistReport]) -> ConnectResponse:
        """Register ``so_id`` as the legitimate incarnation (paper §5.1).

        A connect from an already-registered member indicates a failure and
        triggers the Recovery Protocol: compute the consistent surviving
        prefix, durably log the decision, and release it to the cluster.
        """
        with self._lock:
            self._ingest(fragments)
            is_failure = so_id in self._members
            if is_failure:
                self._awaiting.discard(so_id)  # its fragments just arrived in full
                self._awaiting_changed()
                self._recovered_cv.notify_all()
            else:
                self._log.append({"type": "member", "so_id": so_id})
                self._members.add(so_id)
                self._graph.add_member(so_id)

        if is_failure:
            # -- failure path ---------------------------------------------------
            # Rollback targets on an incomplete view would erase innocent
            # members; wait until every other participant has resent.
            with self._lock:
                self._wait_recovered(exclude={so_id})
            # Snapshot decisions only AFTER the wait: a decision landing
            # during the (up to recovery_timeout) window must filter `valid`.
            decisions = self._all_decisions()
            idx = DecisionIndex(decisions)
            valid = [
                r.vertex.version
                for r in fragments
                if r.vertex.so_id == so_id and not idx.invalidates(r.vertex)
            ]
            surviving = max(valid, default=-1)
            decision = self._decide(so_id, surviving)
            restore_to = decision.targets.get(so_id, -1)
            restore_to = restore_to if restore_to >= 0 else None
            # world must be OUR decision's fsn, not a fresh read: a decision
            # concurrent with the post-_decide window would otherwise ship as
            # world while restore_to predates it — the runtime would set
            # world past its fsn and never apply it. Later decisions in the
            # (fresh) decision list are applied via poll, which is safe.
            boundary, bseq = self._boundary_with_seq()
            return ConnectResponse(
                world=decision.fsn,
                decisions=self._all_decisions(),
                boundary=boundary,
                restore_to=restore_to,
                boundary_seq=bseq,
            )

        # -- first connect ------------------------------------------------------
        # Read world BEFORE decisions: a decision landing between the two
        # reads is then included in `decisions` (filtering `valid`) while
        # `world` predates it, so the runtime still applies it via poll.
        # The unsafe order (fresh world, stale decisions) could adopt a
        # version that decision just invalidated, with world already past
        # its fsn — never applied, permanently wrong state.
        world = self._world()
        decisions = self._all_decisions()
        idx = DecisionIndex(decisions)
        valid = [
            r.vertex.version
            for r in fragments
            if r.vertex.so_id == so_id and not idx.invalidates(r.vertex)
        ]
        # Adoption: an unknown member with durable state (e.g. a fresh
        # coordinator log) resumes from its own latest valid version.
        restore_to = max(valid) if valid else None
        boundary, bseq = self._boundary_with_seq()
        return ConnectResponse(
            world=world,
            decisions=decisions,
            boundary=boundary,
            restore_to=restore_to,
            boundary_seq=bseq,
        )

    def _dedup_reports(
        self, so_id: str, reports: Sequence[PersistReport]
    ) -> List[PersistReport]:
        """Drop reports whose (world, seq) this coordinator already processed
        for ``so_id`` (call with self._lock held). seq=-1 (connect/fragment
        resends rebuilt from disk) is never deduped — full resends must
        always be ingestible."""
        seen = self._report_seen.setdefault(so_id, set())
        out: List[PersistReport] = []
        for r in reports:
            if r.seq >= 0:
                key = (r.vertex.world, r.seq)
                if key in seen:
                    self.dup_reports_dropped += 1
                    continue
                seen.add(key)
            out.append(r)
        if len(seen) > 16384:
            # memory bound: seqs are per-incarnation monotone, so within one
            # world anything far below that world's max can only be a
            # long-stale duplicate whose re-ingest is harmless (graph
            # ingestion is idempotent). The floor is per-world: a restarted
            # incarnation begins a new world at seq 0, and a global floor
            # would erase its live window.
            max_by_world: Dict[int, int] = {}
            for w, s in seen:
                if s > max_by_world.get(w, -1):
                    max_by_world[w] = s
            self._report_seen[so_id] = {
                (w, s) for (w, s) in seen if s >= max_by_world[w] - 8192
            }
        return out

    def report(self, so_id: str, reports: Sequence[PersistReport]) -> List[Vertex]:
        """Ingest persisted-vertex reports; returns the vertices a rollback
        decision has already invalidated (``_ingest`` drops them silently).
        A successful return is therefore an *admission* ack for everything
        not listed — the durable baseline blocks exposure on it, so it must
        not mistake "delivered but dropped" for "inside the view" (an
        invalidated-at-ingest vertex is above its owner's rollback target
        and WILL be rolled back when the decision reaches the runtime)."""
        with self._lock:
            self._ingest(self._dedup_reports(so_id, reports))
            # evaluated over the full incoming batch (including seq-deduped
            # duplicates): admission is a function of the decision set, so a
            # retried flush gets the same verdict its lost ack carried.
            return [r.vertex for r in reports if self._dindex.invalidates(r.vertex)]

    def receive_fragments(self, so_id: str, fragments: Sequence[PersistReport]) -> None:
        """Full fragment resend during coordinator recovery."""
        with self._lock:
            self._ingest(fragments)
            self._awaiting.discard(so_id)
            self._awaiting_changed()
            self._recovered_cv.notify_all()
            self._dirty = True

    def poll(self, so_id: str, known_world: int, known_boundary_seq: int = -1) -> PollResponse:
        # One critical section for resend-check + decision delta + boundary
        # (the seed took the lock three times per poll). CoordinatorShard
        # overrides this with the hook-based variant: its decision/boundary
        # sources live on the DecisionBus and must be reached without the
        # shard lock held (cross-shard deadlock, see the hook comment above).
        with self._lock:
            resend = so_id in self._awaiting
            decisions = self._decisions_after(known_world)
            boundary, seq = self._boundary_locked(known_boundary_seq)
        return PollResponse(
            decisions=decisions,
            boundary=boundary,
            resend_fragments=resend,
            boundary_seq=seq,
        )

    # ------------------------------------------------------------------ #
    # snapshot + compaction (repro.store, DESIGN.md §11)                 #
    # ------------------------------------------------------------------ #
    def checkpoint(self) -> int:
        """Fold the current durable cut into a snapshot and rotate the log;
        returns the new store generation. Safe at any time — the cut is
        taken under the lock, and a crash mid-checkpoint recovers from
        whichever generation the manifest names."""
        with self._lock:
            # freshen the floor first (no-op while the view is incomplete:
            # an empty floor just means nothing retires this round). This
            # may itself fire the auto-compaction trigger — don't snapshot
            # the same cut twice back-to-back if it did.
            gen = self._log.generation
            self._boundary_locked()
            if self._log.generation != gen:
                return self._log.generation
            return self._checkpoint_locked(dict(self._boundary_cache))

    def _retire_decisions_locked(self, floor: Dict[str, int]) -> None:
        """Drop the longest decision prefix whose lost windows every target
        floor has passed (call with self._lock held).

        Soundness (DESIGN.md §11): ``floor[so] > lost[so]`` for a target
        means every vertex the decision could still invalidate is strictly
        below ``so``'s exposure floor — already GC'd from (or about to be
        GC'd from) its fragment store, never resent, never adoptable — and,
        because post-decision reports at the old world are themselves
        invalidated, the floor can only have passed the lost window after
        ``so`` applied the decision, so every live incarnation's world is
        past its fsn and no poll delta can ever need it. Retirement is
        prefix-only so the durable cut records a single ``retired_upto``.
        """
        i = 0
        while i < len(self._decisions):
            d = self._decisions[i]
            if not d.lost or not all(
                floor.get(so, -1) > d.lost.get(so, t) for so, t in d.targets.items()
            ):
                break
            i += 1
        if i:
            self._retired_upto = self._decisions[i - 1].fsn
            del self._decisions[:i]
            del self._decision_fsns[:i]
            self._dindex = DecisionIndex(self._decisions)

    def _checkpoint_locked(self, floor: Dict[str, int]) -> int:
        if self._log.checkpoint_records is None:
            # compaction disabled: no snapshot may be installed, and the
            # in-memory decision list must then match the durable log —
            # don't retire either (the log owns the same contract; this
            # guard just keeps retirement/stats consistent with it)
            return self._log.generation
        if not floor:
            # no live floor (e.g. checkpoint requested right after a restart,
            # before fragment resends complete): fall back to the previous
            # snapshot's floor. Sound because exposure floors never retreat
            # (rollback targets are >= every exposed floor), so the old cut
            # is a valid lower bound and retirement stays conservative.
            floor = dict(self._snapshot_floor)
        self._retire_decisions_locked(floor)
        self._snapshot_floor = dict(floor)
        blob = encode_snapshot(
            CoordinatorSnapshot(
                fsn=self._fsn,
                retired_upto=self._retired_upto,
                members=sorted(self._members),
                decisions=list(self._decisions),
                graph=self._graph.export_state(),
                floor=floor,
                report_seen={so: set(s) for so, s in self._report_seen.items() if s},
            )
        )
        gen = self._log.checkpoint(blob)
        self.checkpoints += 1
        return gen

    # ------------------------------------------------------------------ #
    # introspection                                                      #
    # ------------------------------------------------------------------ #
    def current_boundary(self) -> Optional[Dict[str, int]]:
        return self._boundary()

    def stats(self) -> Dict[str, object]:
        with self._lock:
            _, vertices = self._graph.size()  # counters, not a deep copy
            return {
                "members": sorted(self._members),
                "fsn": self._fsn,
                "decisions": len(self._decisions),
                "retired_upto": self._retired_upto,
                "graph_vertices": vertices,
                "awaiting": sorted(self._awaiting),
                "dup_reports_dropped": self.dup_reports_dropped,
                "checkpoints": self.checkpoints,
                "log_generation": self._log.generation,
                "log_records": self._log.records_since_checkpoint,
            }

    def close(self) -> None:
        self._log.close()
