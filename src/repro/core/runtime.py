"""DSERuntime — per-StateObject speculative execution engine (paper §4, §5.1).

Responsibilities (paper §3): (1) persist / recover / roll back the
StateObject by invoking developer-supplied methods, (2) instrument message
headers to establish dependencies, discard rolled-back messages and delay
cross-epoch messages, (3) protect developer state access via epoch-protected
actions.

Commit ordering (Def 4.1) is enforced by *version relabeling*: receiving a
dependency with watermark ``n`` bumps the in-progress version label to
``max(v_cur, n)`` instead of blocking for local persistence (see DESIGN.md
§2 for the equivalence argument; labels are monotonic watermarks and
persisted-label gaps are allowed). ``strict_commit_ordering=True`` restores
the paper's literal blocking behaviour.
"""
from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Set, TYPE_CHECKING

from .clock import Clock, REAL_CLOCK
from .ids import (
    DecisionIndex,
    Header,
    PersistReport,
    RollbackDecision,
    Vertex,
    decode_metadata,
    encode_metadata,
)
from .epoch import EpochRWLock
from .sthread import DelayMessage, RolledBackError, SThread

if TYPE_CHECKING:  # pragma: no cover
    from .coordinator import Coordinator
    from .state_object import StateObject


@dataclass
class DSEConfig:
    so_id: str
    coordinator: "Coordinator"
    group_commit_interval: float = 0.010  # seconds; paper default 10 ms
    strict_commit_ordering: bool = False
    #: which runtime implementation ``StateObject.Connect`` builds: ``"dse"``
    #: (speculative, this module) or ``"durable"`` (synchronous baseline,
    #: :class:`repro.durable.DurableRuntime`). Same config, same protocol.
    runtime: str = "dse"
    # Jitter persists across the fleet so thousands of nodes do not fsync in
    # lock-step (straggler/burst mitigation; beyond-paper, see DESIGN.md §6).
    persist_jitter: float = 0.0
    barrier_poll_interval: float = 0.002
    user_metadata_fn: Optional[object] = None  # Callable[[], bytes]
    #: time + blocking-primitive source; the simulation harness injects a
    #: virtual clock here (DESIGN.md §8), production uses the real one.
    clock: Clock = REAL_CLOCK


class CrashedError(Exception):
    """Raised by a killed incarnation (failure-injection harness)."""


class DSERuntime:
    #: introspection tag (``"durable"`` in the synchronous baseline subclass)
    kind = "dse"

    def __init__(self, so: "StateObject", config: DSEConfig) -> None:
        self.so = so
        self.config = config
        self.so_id = config.so_id
        self.coordinator = config.coordinator
        self.clock = config.clock

        self._epoch = EpochRWLock(self.clock)
        self._mu = self.clock.rlock()
        self._boundary_cond = self.clock.condition(self._mu)

        self.world = 0
        self._v_cur = 1  # version 0 is the Connect-time snapshot
        self._committed = -1
        self._dirty = False
        self._current_deps: Set[Vertex] = set()
        # deps of persisted-but-not-yet-inside-boundary labels (for the
        # skip-rollback mitigation, paper §5.3) + local label list.
        self._dep_log: Dict[int, FrozenSet[Vertex]] = {}
        self._labels: List[int] = []

        self._decisions: List[RollbackDecision] = []
        #: compacted invalidation index over ``_decisions`` — message
        #: classification is O(deps · log failures), not O(deps · failures)
        self._dindex = DecisionIndex()
        self._boundary: Dict[str, int] = {}
        #: generation of ``_boundary`` as quoted by the coordinator; polls
        #: answering with this seq ship no boundary (nothing moved)
        self._boundary_seq = -1
        self._report_queue: List[PersistReport] = []
        #: per-incarnation flush sequence stamped on each PersistReport so
        #: the coordinator can drop duplicate deliveries (a transport retry
        #: landing after the requeue path already resent the report).
        self._report_seq = 0
        #: world -> highest version whose report the coordinator has ACKED
        #: (a successful ``report`` RPC return); the durable baseline blocks
        #: exposure on this mark.
        self._flushed_marks: Dict[int, int] = {}
        self._last_persist = self.clock.now()
        if config.persist_jitter:
            # crc32, not hash(): PYTHONHASHSEED-salted str hashing would make
            # the jitter offset differ across processes, breaking the
            # (scenario, seed) replay guarantee of DESIGN.md §8
            stable = zlib.crc32(self.so_id.encode())
            self._last_persist += (stable % 1000) / 1000.0 * config.persist_jitter

        self._dead = False
        self._persist_failures: List[BaseException] = []

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def connect(self) -> None:
        """Register with the coordinator; adopt rollback state; make an
        initial durable version so a restore floor always exists.

        The fragment list is O(live state), not O(history): the previous
        incarnation's fragment GC (``_apply_prune`` + ``_resend_fragments``)
        keeps the durable store bounded to the exposure floor and above, so
        a reconnect ships only the live window (DESIGN.md §11). No floor
        filter applies here — a fresh incarnation has no boundary yet, and
        the disk it inherits is already the pruned suffix.
        """
        fragments, _, _ = self._list_fragments()
        resp = self.coordinator.connect(self.so_id, fragments)
        idx = DecisionIndex(resp.decisions)
        with self._mu:
            self.world = resp.world
            self._decisions = list(resp.decisions)
            self._dindex = idx
            self._boundary = dict(resp.boundary or {})
            # Adopt the seq only alongside an actual boundary: connecting
            # during an incomplete view (boundary=None) with a current seq
            # would otherwise gate away the first real boundary ship.
            self._boundary_seq = (
                getattr(resp, "boundary_seq", -1) if resp.boundary is not None else -1
            )

        if resp.restore_to is not None:
            # Restarted (or adopted) incarnation: load the prescribed prefix.
            # Stale blobs above the target (from versions a past decision
            # invalidated) stay on disk but are filtered everywhere by the
            # decision list, which the coordinator replays durably.
            self.so.Restore(resp.restore_to)
            valid = {
                r.vertex.version for r in fragments if not idx.invalidates(r.vertex)
            }
            with self._mu:
                self._committed = resp.restore_to
                self._v_cur = resp.restore_to + 1
                self._labels = sorted(v for v in valid if v <= resp.restore_to)
                self._dep_log = {}
        else:
            # Fresh StateObject: synchronously persist version 0.
            self._persist_now(force_label=0, synchronous=True)
        try:
            self._flush_reports()
        except Exception:
            # Transport failure (partitioned/lossy fabric) must not abort the
            # connect: the reports are requeued and the next Refresh retries
            # them. Raising here would strand the cluster with the dead
            # incarnation still registered (restart never completes).
            pass

    def mark_dead(self) -> None:
        self._dead = True

    def _check_alive(self) -> None:
        if self._dead:
            raise CrashedError(f"{self.so_id}: this incarnation has crashed")

    # ------------------------------------------------------------------ #
    # header classification (instrumentation + partition rules)          #
    # ------------------------------------------------------------------ #
    def classify_header(self, header: Optional[Header]) -> str:
        """'ok' | 'discard' | 'delay' per Defs 4.1/4.3."""
        if header is None:
            return "ok"
        with self._mu:
            for dep in header.deps:
                if dep.world > self.world:
                    return "delay"
                if dep.world < self.world:
                    # Either rolled back, or the surviving prefix of an older
                    # epoch whose sender will retry post-recovery — both
                    # discard (Def 4.3, conservative per the paper's rule).
                    return "discard"
                if self._dindex.invalidates(dep):
                    return "discard"
        return "ok"

    def any_invalid(self, deps: Iterable[Vertex]) -> bool:
        with self._mu:
            return any(
                dep.world < self.world or self._dindex.invalidates(dep)
                for dep in deps
            )

    # ------------------------------------------------------------------ #
    # actions (paper §3.1)                                               #
    # ------------------------------------------------------------------ #
    def start_action(self, header: Optional[Header] = None) -> bool:
        self._check_alive()
        self._epoch.acquire_shared()
        try:
            status = self.classify_header(header)
            if status == "delay":
                raise DelayMessage()
            if status == "discard":
                self._epoch.release_shared()
                return False
            if header is not None:
                n = header.max_version_for()
                if self.config.strict_commit_ordering:
                    # Paper-literal Def 4.1: block the action until local
                    # persistence has caught up with the sender watermark.
                    while True:
                        with self._mu:
                            if self._v_cur >= n:
                                break
                        self._epoch.release_shared()
                        self.maybe_persist(force=True)
                        self._epoch.acquire_shared()
                with self._mu:
                    if n > self._v_cur:
                        self._v_cur = n  # relabel (monotone watermark)
                    self._current_deps |= {d for d in header.deps if d.so_id != self.so_id}
            with self._mu:
                self._dirty = True
            return True
        except DelayMessage:
            self._epoch.release_shared()
            raise
        except Exception:
            self._epoch.release_shared()
            raise

    def end_action(self) -> Header:
        with self._mu:
            h = Header.of(Vertex(self.so_id, self.world, self._v_cur))
        self._epoch.release_shared()
        return h

    def abort_action(self) -> None:
        """Release action protection without emitting a header (the effects,
        if any, still belong to the in-progress version)."""
        self._epoch.release_shared()

    def current_vertex(self) -> Vertex:
        with self._mu:
            return Vertex(self.so_id, self.world, self._v_cur)

    # ------------------------------------------------------------------ #
    # sthreads                                                           #
    # ------------------------------------------------------------------ #
    def detach(self) -> SThread:
        """End the calling action, producing an sthread carrying its deps."""
        with self._mu:
            deps = {Vertex(self.so_id, self.world, self._v_cur)}
        self._epoch.release_shared()
        return SThread(self, deps)

    def merge(self, sthread: SThread) -> bool:
        """Logically send sthread -> StateObject and start an action."""
        try:
            header = sthread.Send()
        except RolledBackError:
            return False
        while True:
            try:
                return self.start_action(header)
            except DelayMessage:
                # The sthread observed a future failure epoch; catch up by
                # applying pending decisions, then retry (Def 4.3 delay).
                try:
                    self.refresh()
                except TimeoutError:
                    pass  # fabric hiccup: retry the catch-up next iteration

    # ------------------------------------------------------------------ #
    # persistence (group commit)                                         #
    # ------------------------------------------------------------------ #
    def maybe_persist(self, force: bool = False) -> Optional[int]:
        self._check_alive()
        now = self.clock.now()
        with self._mu:
            due = (now - self._last_persist) >= self.config.group_commit_interval
            if not force and not (due and self._dirty):
                return None
        return self._persist_now()

    def _persist_now(self, force_label: Optional[int] = None, synchronous: bool = False) -> int:
        label, done, _world = self._persist_begin(force_label)
        if synchronous:
            done.wait()
            try:
                self._flush_reports()
            except Exception:
                pass  # connect-time flush: requeued, retried next Refresh
        return label

    def _persist_begin(self, force_label: Optional[int] = None):
        """Snapshot + kick off the async Persist IO; returns ``(label,
        done_event, world)`` — the event sets once the version is durable
        and its report is queued; ``world`` is the epoch the snapshot (and
        its report) actually carries, taken under the exclusive epoch so no
        decision can interleave. The synchronous durable baseline builds
        its per-action commit wait on this hook."""
        self._epoch.acquire_exclusive()
        try:
            with self._mu:
                label = self._v_cur if force_label is None else force_label
                deps = frozenset(self._current_deps)
                self._current_deps = set()
                self._dep_log[label] = deps
                self._labels.append(label)
                self._v_cur = label + 1
                self._dirty = False
                self._last_persist = self.clock.now()
                world = self.world
            user_meta = b""
            if self.config.user_metadata_fn is not None:
                user_meta = self.config.user_metadata_fn()  # type: ignore[operator]
            meta = encode_metadata(world, label, deps, user=user_meta)
            done = self.clock.event()

            def _callback() -> None:
                with self._mu:
                    if label > self._committed:
                        self._committed = label
                    seq = self._report_seq
                    self._report_seq += 1
                    self._report_queue.append(
                        PersistReport(
                            Vertex(self.so_id, world, label), tuple(deps), seq=seq
                        )
                    )
                done.set()

            self.so.Persist(label, meta, _callback)
        finally:
            self._epoch.release_exclusive()
        return label, done, world

    # ------------------------------------------------------------------ #
    # refresh: background protocol driving (paper Table 2)               #
    # ------------------------------------------------------------------ #
    def refresh(self) -> None:
        self._check_alive()
        self.maybe_persist()
        self._flush_reports()
        self._poll_coordinator()

    def _flush_reports(self) -> None:
        with self._mu:
            reports, self._report_queue = self._report_queue, []
        if not reports:
            return
        # Dedup the batch by vertex: requeue interleavings can only ever
        # leave one copy of a fragment in OUR queue, but belt-and-braces here
        # keeps the wire batch canonical (and the coordinator additionally
        # drops cross-batch duplicates by (so_id, world, seq) — a transport
        # retry of a timed-out flush can land AFTER the requeued resend).
        seen = set()
        batch: List[PersistReport] = []
        for r in reports:
            key = (r.vertex.world, r.vertex.version)
            if key in seen:
                continue
            seen.add(key)
            batch.append(r)
        try:
            rejected = self.coordinator.report(self.so_id, batch)
        except Exception:
            # Transport failure (lossy / partitioned fabric): the coordinator
            # may or may not have seen these fragments, so requeue them for
            # the next Refresh round — silently dropping them could stall the
            # boundary forever; the coordinator-side seq dedup makes the
            # at-least-once resend single-count.
            with self._mu:
                self._report_queue = batch + self._report_queue
            raise
        # Admission marks: a delivered report a decision already invalidated
        # is NOT inside the coordinator's view (it will be rolled back), so
        # it must not advance the durable baseline's exposure floor. An
        # old/mocked coordinator returning None means "all admitted".
        dropped = {(v.world, v.version) for v in (rejected or ())}
        with self._mu:
            for r in batch:
                w = r.vertex.world
                if (w, r.vertex.version) in dropped:
                    continue
                if r.vertex.version > self._flushed_marks.get(w, -1):
                    self._flushed_marks[w] = r.vertex.version

    def _poll_coordinator(self) -> None:
        with self._mu:
            known = self.world
            known_seq = self._boundary_seq
        resp = self.coordinator.poll(self.so_id, known, known_seq)
        if resp.resend_fragments:
            self._resend_fragments()
            with self._mu:
                # A resend request means the coordinator restarted: its
                # boundary_seq counter restarted too, so forget ours — the
                # next poll must ship the full boundary again.
                self._boundary_seq = -1
        for d in sorted(resp.decisions, key=lambda d: d.fsn):
            self._apply_decision(d)  # Recovery Sequencing Rule (Def 4.2)
        if resp.boundary is not None:
            with self._mu:
                # Notify only on actual progress: concurrent barriers each
                # drive _poll_coordinator, and unconditional notify_all lets
                # them wake each other in a storm that (under zero-latency
                # virtual time) never lets the poll interval elapse.
                changed = resp.boundary != self._boundary
                self._boundary = dict(resp.boundary)
                self._boundary_seq = resp.boundary_seq
                if changed:
                    self._boundary_cond.notify_all()
            self._apply_prune()

    def _list_fragments(
        self, floor: int = -1, dindex: Optional[DecisionIndex] = None
    ) -> tuple:
        """Rebuild PersistReports from the durable store as ``(fragments,
        dropped, anchor)``, skipping versions that are strictly below the
        durable **anchor** — the greatest persisted label <= the exposure
        floor (the floor is a watermark and may sit in a label gap from
        relabeling; the anchor is the label that actually carries the floor
        state, and always ships) — or that a known rollback decision has
        invalidated (stale blobs above an old target: the coordinator would
        drop them at ingest anyway)."""
        decoded = []
        for version, meta in self.so.ListVersions():
            try:
                world, v, deps, _user = decode_metadata(meta)
            except Exception:
                continue
            decoded.append((v, world, deps))

        def valid(v: int, world: int) -> bool:
            return dindex is None or not dindex.invalidates(Vertex(self.so_id, world, v))

        # the anchor must be elected among VALID labels: a decision-
        # invalidated stale blob sitting in (target, floor] would otherwise
        # win the max, get dropped by the decision filter below, and take
        # the genuine floor carrier (every valid label under it) with it
        anchor = max((v for v, w, _ in decoded if v <= floor and valid(v, w)), default=-1)
        fragments: List[PersistReport] = []
        dropped = 0
        for v, world, deps in decoded:
            if v < anchor or not valid(v, world):
                dropped += 1
                continue
            fragments.append(PersistReport(Vertex(self.so_id, world, v), deps))
        return fragments, dropped, anchor

    def _resend_fragments(self) -> None:
        with self._mu:
            floor = self._boundary.get(self.so_id, -1)
            idx = self._dindex
        fragments, dropped, anchor = self._list_fragments(floor, idx)
        # The coordinator must never need a GC'd fragment: whenever history
        # was dropped, the anchor label (whose watermark the coordinator's
        # durable snapshot already records) must still be in the resend.
        assert not dropped or anchor < 0 or any(
            r.vertex.version == anchor for r in fragments
        ), f"{self.so_id}: fragment GC dropped the anchor ({anchor}, floor={floor})"
        self.coordinator.receive_fragments(self.so_id, fragments)

    def _apply_prune(self) -> None:
        with self._mu:
            b = self._boundary.get(self.so_id, -1)
            floor_candidates = [l for l in self._labels if l <= b]
            if len(floor_candidates) < 2:
                return
            floor = floor_candidates[-1]
            self._labels = [l for l in self._labels if l >= floor]
            for l in [l for l in self._dep_log if l < floor]:
                self._dep_log.pop(l, None)
        self.so.Prune(floor)

    # ------------------------------------------------------------------ #
    # recovery (paper §4.2 Recovery Protocol + §5.3 mitigation)          #
    # ------------------------------------------------------------------ #
    def _apply_decision(self, d: RollbackDecision) -> None:
        with self._mu:
            if d.fsn <= self.world:
                return
        self._epoch.acquire_exclusive()
        try:
            with self._mu:
                if d.fsn <= self.world:
                    return
                target = d.targets.get(self.so_id)
                inmem_deps: Set[Vertex] = set(self._current_deps)
                for label, deps in self._dep_log.items():
                    if target is None or label > target:
                        inmem_deps |= deps
                own_prefix_intact = target is None or target >= self._committed
                clean = not any(d.invalidates(dep) for dep in inmem_deps)
                can_skip = own_prefix_intact and clean
            if can_skip:
                # §5.3: participants not exposed to speculative (now lost)
                # state keep their in-memory content; only the epoch advances.
                with self._mu:
                    self.world = d.fsn
                    self._decisions.append(d)
                    self._dindex.add(d)
            else:
                assert target is not None
                # A decision can assign -1 when our synchronous v0 report was
                # still crossing the fabric when it was computed; our durable
                # floor (the Connect-time snapshot, dependency-free) is always
                # a safe restore point, so clamp up to it.
                with self._mu:
                    floor = self._labels[0] if self._labels else 0
                target = max(target, floor)
                self.so.Restore(target)
                with self._mu:
                    self.world = d.fsn
                    self._decisions.append(d)
                    self._dindex.add(d)
                    self._committed = min(self._committed, target)
                    self._v_cur = target + 1
                    self._current_deps = set()
                    self._dep_log = {l: v for l, v in self._dep_log.items() if l <= target}
                    self._labels = [l for l in self._labels if l <= target]
                    self._dirty = False
                    self._report_queue = [
                        r for r in self._report_queue if r.vertex.version <= target
                    ]
        finally:
            self._epoch.release_exclusive()

    # ------------------------------------------------------------------ #
    # barriers (paper §3.2)                                              #
    # ------------------------------------------------------------------ #
    def barrier(self, deps: FrozenSet[Vertex], timeout: Optional[float] = None) -> None:
        """Block until every vertex in ``deps`` is inside the recoverable
        boundary. Our own pending state is force-persisted once so local
        durability is never the reason a barrier waits a full group-commit
        period."""
        deadline = None if timeout is None else self.clock.now() + timeout
        with self._mu:
            needs_local = any(
                dep.so_id == self.so_id and dep.version > self._committed for dep in deps
            )
        if needs_local:
            self.maybe_persist(force=True)

        while True:
            if self.any_invalid(deps):
                raise RolledBackError("barrier deps were rolled back")
            with self._mu:
                if all(self._boundary.get(dep.so_id, -1) >= dep.version for dep in deps):
                    return
            try:
                self._flush_reports()
                self._poll_coordinator()
            except TimeoutError:
                # Transient fabric failure (partition/loss): transport errors
                # are retryable everywhere else; only the barrier's OWN
                # deadline below may raise TimeoutError to the caller.
                pass
            with self._mu:
                if all(self._boundary.get(dep.so_id, -1) >= dep.version for dep in deps):
                    return
                remaining = self.config.barrier_poll_interval
                if deadline is not None:
                    remaining = min(remaining, deadline - self.clock.now())
                    if remaining <= 0:
                        raise TimeoutError(f"barrier timed out waiting for {set(deps)}")
                self._boundary_cond.wait(timeout=remaining)

    # ------------------------------------------------------------------ #
    # introspection                                                      #
    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, object]:
        with self._mu:
            return {
                "so_id": self.so_id,
                "runtime": self.kind,
                "world": self.world,
                "v_cur": self._v_cur,
                "committed": self._committed,
                "boundary": dict(self._boundary),
                "decisions": len(self._decisions),
                "labels": list(self._labels),
            }

    @property
    def boundary(self) -> Dict[str, int]:
        with self._mu:
            return dict(self._boundary)
