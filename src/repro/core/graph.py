"""Recovery dependency graph and the boundary / rollback fixpoints (paper §4.2–4.3).

The graph is stored in *watermark* form: for each StateObject we keep the
sorted list of persisted version labels and, per label, the dependency list
``[(dep_so, dep_version), ...]``. Prefix-recoverability semantics mean a
dependency on version ``n`` of ``B`` is satisfied by any recovered watermark
``>= n`` of ``B`` — precedence edges (paper: "implicitly by precedence") are
therefore implicit, and persisted-label *gaps* (from version relabeling, see
DESIGN.md §2) are harmless.

Two closely-related fixpoints are computed here:

* ``recoverable_boundary`` — the maximal closure of durable vertices; the
  cut behind which results are non-speculative (Boundary Protocol).
* ``rollback_targets`` — identical computation with the failed SO's durable
  watermark truncated to what actually survived; the consistent prefix every
  participant restores to (Recovery Protocol).

Because the commit ordering rule guarantees dep.version <= vertex.version,
every global watermark set {v : v.version <= t} is a closure, so the
fixpoint always terminates at a non-degenerate cut (no domino effect).

Boundary maintenance is *incremental* (DESIGN.md §9): alongside the graph we
keep the current boundary, a waiters index (reverse dependencies of blocked
vertices), and the pending frontier, so ingesting one PersistReport costs
O(its deps + waiters it unblocks) instead of re-running the global fixpoint.
The from-scratch fixpoint is retained as the slow-path oracle — rollback /
truncation fall back to it, and tests cross-check equivalence.
"""
from __future__ import annotations

import bisect
import heapq
import threading
from collections import deque
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple


DepList = List[Tuple[str, int]]  # [(dep_so_id, dep_version_watermark)]


class DependencyGraph:
    """Coordinator-side (possibly stale) view of the persisted dependency graph.

    Thread-safe; all mutation happens under one lock (the coordinator calls
    are already serialized, but services may query concurrently).
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        # so_id -> {version -> deps}
        self._deps: Dict[str, Dict[int, DepList]] = {}
        # so_id -> sorted list of persisted version labels
        self._labels: Dict[str, List[int]] = {}

        # -- incremental boundary state (all guarded by self._lock) --------
        # current boundary watermark per member (== oracle when _inc_valid)
        self._inc_bound: Dict[str, int] = {}
        # waiters index: dep_so -> heap of (required_version, waiting_so);
        # when dep_so's watermark reaches required_version, waiting_so gets
        # another advance attempt.
        self._waiters: Dict[str, List[Tuple[int, str]]] = {}
        # so -> label it is currently registered as blocked at (dedups
        # waiter heap entries across repeated failed attempts at one label)
        self._blocked: Dict[str, int] = {}
        # monotone change counter: bumps whenever the boundary mapping can
        # have changed (watermark advance, new member, rebuild)
        self._inc_version = 0
        # False after truncate/remove_member: the next incremental query
        # rebuilds from the fixpoint oracle (rollback is the rare path)
        self._inc_valid = True
        # set when a blocked dep is persisted-but-unadmitted — the only
        # situation where same-version dependency cycles can stall the
        # bottom-up advance and the frontier rescue pass must run
        self._maybe_cycle = False

    # -- mutation --------------------------------------------------------------
    def add_member(self, so_id: str) -> None:
        with self._lock:
            if so_id not in self._labels:
                self._deps[so_id] = {}
                self._labels[so_id] = []
                self._inc_bound.setdefault(so_id, -1)
                self._inc_version += 1  # boundary mapping gains a key

    def remove_member(self, so_id: str) -> None:
        with self._lock:
            self._deps.pop(so_id, None)
            self._labels.pop(so_id, None)
            self._invalidate_incremental()

    def report_persistent(self, so_id: str, version: int, deps: Iterable[Tuple[str, int]]) -> None:
        with self._lock:
            self.add_member(so_id)
            per = self._deps[so_id]
            dep_list = list(deps)
            if version not in per:
                bisect.insort(self._labels[so_id], version)
            elif per[version] != dep_list and self._blocked.get(so_id) == version:
                # The blocked label's dep list changed (protocol traffic never
                # mutates a persisted vertex, but this public API allows it):
                # drop the registration dedup so the cascade below re-registers
                # waiters for the NEW deps instead of waiting on stale ones.
                self._blocked.pop(so_id, None)
            per[version] = dep_list
            if not self._inc_valid:
                return
            if version > self._inc_bound.get(so_id, -1):
                self._cascade(so_id)
            elif any(
                dep_so != so_id and self._inc_bound.get(dep_so, -1) < dep_version
                for dep_so, dep_version in dep_list
            ):
                # Out-of-order delivery landed a vertex BELOW the admitted
                # watermark with an unsatisfied dep: the admitted prefix is
                # no longer a closure and advance-only maintenance cannot
                # lower it — rebuild from the oracle on the next query.
                self._invalidate_incremental()

    def merge_from(self, other: "DependencyGraph") -> None:
        """Absorb another graph's vertices (sharded-coordinator merge rule:
        the global view is the union of per-shard fragments)."""
        snap = other.snapshot()
        with self._lock:
            for so, per in snap.items():
                self.add_member(so)
                for v, deps in per.items():
                    self.report_persistent(so, v, deps)

    def truncate(self, so_id: str, keep_upto: int) -> None:
        """Drop vertices of ``so_id`` with version > keep_upto (rollback)."""
        with self._lock:
            labels = self._labels.get(so_id, [])
            cut = bisect.bisect_right(labels, keep_upto)
            if cut == len(labels):
                return  # nothing dropped: boundary unaffected
            for v in labels[cut:]:
                self._deps[so_id].pop(v, None)
            self._labels[so_id] = labels[:cut]
            self._invalidate_incremental()

    def prune(self, so_id: str, below: int) -> None:
        """Forget dep lists for versions <= ``below`` (they are inside the
        boundary forever; keeping only the watermark is sufficient)."""
        with self._lock:
            labels = self._labels.get(so_id, [])
            if not labels:
                return
            cut = bisect.bisect_right(labels, below)
            if cut <= 1:
                return
            if self._inc_valid and labels[cut - 1] > self._inc_bound.get(so_id, -1):
                # Pruning past the incremental watermark (a sharded caller
                # pruning to an externally-computed boundary) can remove a
                # blocked label the incremental state still tracks: rebuild.
                # The coordinator's own prune-at-boundary never takes this
                # branch (below == the incremental watermark).
                self._invalidate_incremental()
            # keep the highest pruned label as the floor watermark
            for v in labels[: cut - 1]:
                self._deps[so_id].pop(v, None)
                self._deps[so_id].setdefault(labels[cut - 1], [])
            self._labels[so_id] = labels[cut - 1 :]

    # -- queries ---------------------------------------------------------------
    def members(self) -> List[str]:
        with self._lock:
            return list(self._labels.keys())

    def committed_watermarks(self) -> Dict[str, int]:
        with self._lock:
            return {so: (labels[-1] if labels else -1) for so, labels in self._labels.items()}

    def snapshot(self) -> Dict[str, Dict[int, DepList]]:
        with self._lock:
            return {so: {v: list(d) for v, d in per.items()} for so, per in self._deps.items()}

    def size(self) -> Tuple[int, int]:
        """(members, vertices) — O(members) counters for stats/telemetry,
        without the full deep copy ``snapshot()`` makes."""
        with self._lock:
            return len(self._labels), sum(len(ls) for ls in self._labels.values())

    # -- durable-cut export/restore (repro.store, DESIGN.md §11) ---------------
    def export_state(self) -> Dict[str, List[Tuple[int, DepList]]]:
        """The retained view as ``{so: [(label, deps), ...]}`` (labels
        sorted). Because ``prune`` collapses everything below the exposure
        floor to the floor watermark, this is the graph *at the floor* —
        O(live state), the shape the coordinator snapshot persists."""
        with self._lock:
            return {
                so: [(v, list(self._deps[so].get(v, ()))) for v in labels]
                for so, labels in self._labels.items()
            }

    def restore_state(self, state: Dict[str, List[Tuple[int, DepList]]]) -> None:
        """Install an exported view (snapshot recovery). Replaces same-SO
        content wholesale; the incremental boundary state is rebuilt from
        the fixpoint oracle on the next query — the same fall-back the
        rollback path uses, so the §9 equivalence property covers it."""
        with self._lock:
            for so, entries in state.items():
                self._deps[so] = {v: list(deps) for v, deps in entries}
                self._labels[so] = sorted(self._deps[so])
                self._inc_bound.setdefault(so, -1)
            self._invalidate_incremental()

    # -- fixpoints ---------------------------------------------------------------
    def recoverable_boundary(
        self,
        committed_override: Optional[Mapping[str, int]] = None,
        external: Optional[Mapping[str, int]] = None,
    ) -> Dict[str, int]:
        """Greatest closure of durable vertices, as per-SO version watermarks.

        ``committed_override`` truncates specific SOs' durable watermarks
        (used by the rollback computation for the failed SO's surviving
        prefix). Returns ``{so_id: watermark}``; a watermark of -1 means
        "nothing recoverable yet" (version labels start at 0).

        ``external`` supplies watermark estimates for SOs this graph does not
        own (sharded deployment: each shard holds only its members' fragments,
        and the global boundary is the fixpoint of per-shard boundaries under
        exchanged estimates — see DESIGN.md §7). External SOs are never cut
        by this graph; only this graph's members appear in the result.
        """
        with self._lock:
            return self._fixpoint_locked(committed_override, external)

    def _fixpoint_locked(
        self,
        committed_override: Optional[Mapping[str, int]] = None,
        external: Optional[Mapping[str, int]] = None,
    ) -> Dict[str, int]:
        bound: Dict[str, int] = {}
        for so, labels in self._labels.items():
            b = labels[-1] if labels else -1
            if committed_override and so in committed_override:
                b = min(b, committed_override[so])
            bound[so] = b
        if external:
            for so, w in external.items():
                bound.setdefault(so, w)

        changed = True
        while changed:
            changed = False
            for so, per_version in self._deps.items():
                b = bound.get(so, -1)
                for v in sorted(ver for ver in per_version if ver <= b):
                    for dep_so, dep_version in per_version[v]:
                        if dep_so == so:
                            continue  # precedence is implicit
                        if bound.get(dep_so, -1) < dep_version:
                            # v (and everything after) cannot be in the
                            # closure: cut this SO's watermark below v.
                            bound[so] = v - 1
                            changed = True
                            break
                    if bound[so] < v:
                        break
        return {so: b for so, b in bound.items() if so in self._labels}

    # -- incremental boundary (DESIGN.md §9) ------------------------------------
    def incremental_boundary(self) -> Tuple[int, Dict[str, int]]:
        """Current recoverable boundary via incremental maintenance.

        Returns ``(change_version, {so: watermark})``: ``change_version`` is
        a monotone counter bumped whenever the boundary mapping may have
        changed, so callers can skip rebuilding/diffing the dict (and the
        coordinator can answer polls with "nothing moved") in O(1).
        Equals ``recoverable_boundary()`` — property-tested in
        ``tests/test_incremental_boundary.py``.
        """
        with self._lock:
            if not self._inc_valid:
                self._rebuild_incremental_locked()
            return self._inc_version, {
                so: self._inc_bound.get(so, -1) for so in self._labels
            }

    def boundary_version(self) -> int:
        with self._lock:
            if not self._inc_valid:
                self._rebuild_incremental_locked()
            return self._inc_version

    def _invalidate_incremental(self) -> None:
        # rollback / member removal can LOWER watermarks, which the
        # advance-only incremental state cannot express: fall back to the
        # oracle on the next query (failures are the rare path).
        self._inc_valid = False

    def _rebuild_incremental_locked(self) -> None:
        self._inc_bound = dict(self._fixpoint_locked())
        self._waiters = {}
        self._blocked = {}
        self._inc_valid = True
        self._inc_version += 1
        # Register waiters for every member stuck below its top label so
        # future report ingestions cascade; the oracle is the greatest
        # closure, so these attempts cannot advance anything.
        queue: Deque[str] = deque(self._labels.keys())
        while queue:
            self._advance_one(queue.popleft(), queue)
        self._maybe_cycle = False

    def _cascade(self, so_id: str) -> None:
        """Advance ``so_id``'s watermark as far as possible and ripple
        through registered waiters; run the frontier rescue pass if a
        potential same-version dependency cycle was observed."""
        queue: Deque[str] = deque((so_id,))
        while queue:
            self._advance_one(queue.popleft(), queue)
        if self._maybe_cycle:
            self._maybe_cycle = False
            self._rescue_locked()

    def _advance_one(self, so: str, queue: Deque[str]) -> bool:
        """Admit ``so``'s pending labels in order while their deps are
        satisfied; on a block, cut to v-1 (matching the oracle's cut rule)
        and register a waiter. Returns True if the watermark moved."""
        labels = self._labels.get(so)
        if labels is None:
            return False
        per_version = self._deps[so]
        b = self._inc_bound.get(so, -1)
        start = b
        i = bisect.bisect_right(labels, b)
        unsatisfied: List[Tuple[str, int]] = []
        while i < len(labels):
            v = labels[i]
            for dep_so, dep_version in per_version.get(v, ()):
                if dep_so == so:
                    continue  # precedence is implicit
                if self._inc_bound.get(dep_so, -1) < dep_version:
                    unsatisfied.append((dep_so, dep_version))
            if unsatisfied:
                b = max(b, v - 1)  # oracle cut semantics: everything < v is in
                break
            b = v
            i += 1
        if not unsatisfied:
            self._blocked.pop(so, None)
        else:
            v = labels[i]
            if self._blocked.get(so) != v:
                # Register a waiter on EVERY unsatisfied dep: any of them can
                # be the last to be satisfied, and each such advance must
                # re-attempt this SO. (Once registered at this label, the
                # remaining entries persist in the heaps — entries pop only
                # when their requirement is satisfied — so re-attempts at the
                # same label skip re-registration.)
                self._blocked[so] = v
                for dep_so, dep_version in unsatisfied:
                    heapq.heappush(
                        self._waiters.setdefault(dep_so, []), (dep_version, so)
                    )
            # A blocking dep that is already persisted but not admitted means
            # its owner is itself blocked: only a dependency cycle (all
            # members at equal versions, by the commit ordering rule) or a
            # longer blocked chain looks like this — schedule the rescue.
            # Checked on every attempt, not just at registration: the attempt
            # satisfying the last acyclic dep must trigger it.
            for dep_so, dep_version in unsatisfied:
                dep_labels = self._labels.get(dep_so)
                if dep_labels and dep_labels[-1] >= dep_version:
                    self._maybe_cycle = True
                    break
        if b != start:
            self._inc_bound[so] = b
            self._inc_version += 1
            self._wake(so, b, queue)
            return True
        return False

    def _wake(self, so: str, watermark: int, queue: Deque[str]) -> None:
        heap = self._waiters.get(so)
        while heap and heap[0][0] <= watermark:
            _, waiting = heapq.heappop(heap)
            queue.append(waiting)

    def _rescue_locked(self) -> None:
        """Frontier group admission: same-version dependency cycles (legal —
        the commit ordering rule only forces dep.version <= vertex.version)
        cannot be admitted one vertex at a time. Take the next unadmitted
        label of every member as a candidate set, run the removal fixpoint
        restricted to those candidates, and admit survivors as a group.
        Iterated to quiescence this reaches the oracle's greatest closure
        (DESIGN.md §9) at O(pending frontier) — not O(history) — cost."""
        progressed = True
        while progressed:
            progressed = False
            cand: Dict[str, int] = {}
            for so, labels in self._labels.items():
                i = bisect.bisect_right(labels, self._inc_bound.get(so, -1))
                if i < len(labels):
                    cand[so] = labels[i]
            removed = True
            while removed and cand:
                removed = False
                for so in list(cand):
                    v = cand.get(so)
                    if v is None:
                        continue
                    for dep_so, dep_version in self._deps[so].get(v, ()):
                        if dep_so == so:
                            continue
                        tb = cand.get(dep_so, self._inc_bound.get(dep_so, -1))
                        if tb < dep_version:
                            del cand[so]
                            removed = True
                            break
            if cand:
                progressed = True
                queue: Deque[str] = deque()
                for so, v in cand.items():
                    self._inc_bound[so] = v
                    self._inc_version += 1
                    self._blocked.pop(so, None)
                for so, v in cand.items():
                    self._wake(so, v, queue)
                    queue.append(so)  # keep advancing past the admitted label
                while queue:
                    self._advance_one(queue.popleft(), queue)
        self._maybe_cycle = False

    def snap_to_labels(self, watermarks: Mapping[str, int]) -> Dict[str, int]:
        """Snap each watermark down to the greatest persisted label <= it.

        Restore targets must be loadable versions; -1 means the initial
        (Connect-time) version 0 snapshot does not exist yet, which cannot
        happen in practice because Connect persists version 0 synchronously.
        """
        with self._lock:
            out: Dict[str, int] = {}
            for so, w in watermarks.items():
                labels = self._labels.get(so, [])
                i = bisect.bisect_right(labels, w)
                out[so] = labels[i - 1] if i > 0 else -1
            return out

    def rollback_targets(self, failed_so: str, surviving: int) -> Dict[str, int]:
        """Consistent prefix after ``failed_so`` lost every version > ``surviving``."""
        bound = self.recoverable_boundary({failed_so: surviving})
        return self.snap_to_labels(bound)
