"""Recovery dependency graph and the boundary / rollback fixpoints (paper §4.2–4.3).

The graph is stored in *watermark* form: for each StateObject we keep the
sorted list of persisted version labels and, per label, the dependency list
``[(dep_so, dep_version), ...]``. Prefix-recoverability semantics mean a
dependency on version ``n`` of ``B`` is satisfied by any recovered watermark
``>= n`` of ``B`` — precedence edges (paper: "implicitly by precedence") are
therefore implicit, and persisted-label *gaps* (from version relabeling, see
DESIGN.md §2) are harmless.

Two closely-related fixpoints are computed here:

* ``recoverable_boundary`` — the maximal closure of durable vertices; the
  cut behind which results are non-speculative (Boundary Protocol).
* ``rollback_targets`` — identical computation with the failed SO's durable
  watermark truncated to what actually survived; the consistent prefix every
  participant restores to (Recovery Protocol).

Because the commit ordering rule guarantees dep.version <= vertex.version,
every global watermark set {v : v.version <= t} is a closure, so the
fixpoint always terminates at a non-degenerate cut (no domino effect).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Tuple


DepList = List[Tuple[str, int]]  # [(dep_so_id, dep_version_watermark)]


class DependencyGraph:
    """Coordinator-side (possibly stale) view of the persisted dependency graph.

    Thread-safe; all mutation happens under one lock (the coordinator calls
    are already serialized, but services may query concurrently).
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        # so_id -> {version -> deps}
        self._deps: Dict[str, Dict[int, DepList]] = {}
        # so_id -> sorted list of persisted version labels
        self._labels: Dict[str, List[int]] = {}

    # -- mutation --------------------------------------------------------------
    def add_member(self, so_id: str) -> None:
        with self._lock:
            self._deps.setdefault(so_id, {})
            self._labels.setdefault(so_id, [])

    def remove_member(self, so_id: str) -> None:
        with self._lock:
            self._deps.pop(so_id, None)
            self._labels.pop(so_id, None)

    def report_persistent(self, so_id: str, version: int, deps: Iterable[Tuple[str, int]]) -> None:
        with self._lock:
            self.add_member(so_id)
            if version not in self._deps[so_id]:
                bisect.insort(self._labels[so_id], version)
            self._deps[so_id][version] = list(deps)

    def merge_from(self, other: "DependencyGraph") -> None:
        """Absorb another graph's vertices (sharded-coordinator merge rule:
        the global view is the union of per-shard fragments)."""
        snap = other.snapshot()
        with self._lock:
            for so, per in snap.items():
                self.add_member(so)
                for v, deps in per.items():
                    self.report_persistent(so, v, deps)

    def truncate(self, so_id: str, keep_upto: int) -> None:
        """Drop vertices of ``so_id`` with version > keep_upto (rollback)."""
        with self._lock:
            labels = self._labels.get(so_id, [])
            cut = bisect.bisect_right(labels, keep_upto)
            for v in labels[cut:]:
                self._deps[so_id].pop(v, None)
            self._labels[so_id] = labels[:cut]

    def prune(self, so_id: str, below: int) -> None:
        """Forget dep lists for versions <= ``below`` (they are inside the
        boundary forever; keeping only the watermark is sufficient)."""
        with self._lock:
            labels = self._labels.get(so_id, [])
            if not labels:
                return
            cut = bisect.bisect_right(labels, below)
            if cut <= 1:
                return
            # keep the highest pruned label as the floor watermark
            for v in labels[: cut - 1]:
                self._deps[so_id].pop(v, None)
                self._deps[so_id].setdefault(labels[cut - 1], [])
            self._labels[so_id] = labels[cut - 1 :]

    # -- queries ---------------------------------------------------------------
    def members(self) -> List[str]:
        with self._lock:
            return list(self._labels.keys())

    def committed_watermarks(self) -> Dict[str, int]:
        with self._lock:
            return {so: (labels[-1] if labels else -1) for so, labels in self._labels.items()}

    def snapshot(self) -> Dict[str, Dict[int, DepList]]:
        with self._lock:
            return {so: {v: list(d) for v, d in per.items()} for so, per in self._deps.items()}

    # -- fixpoints ---------------------------------------------------------------
    def recoverable_boundary(
        self,
        committed_override: Optional[Mapping[str, int]] = None,
        external: Optional[Mapping[str, int]] = None,
    ) -> Dict[str, int]:
        """Greatest closure of durable vertices, as per-SO version watermarks.

        ``committed_override`` truncates specific SOs' durable watermarks
        (used by the rollback computation for the failed SO's surviving
        prefix). Returns ``{so_id: watermark}``; a watermark of -1 means
        "nothing recoverable yet" (version labels start at 0).

        ``external`` supplies watermark estimates for SOs this graph does not
        own (sharded deployment: each shard holds only its members' fragments,
        and the global boundary is the fixpoint of per-shard boundaries under
        exchanged estimates — see DESIGN.md §7). External SOs are never cut
        by this graph; only this graph's members appear in the result.
        """
        with self._lock:
            bound: Dict[str, int] = {}
            for so, labels in self._labels.items():
                b = labels[-1] if labels else -1
                if committed_override and so in committed_override:
                    b = min(b, committed_override[so])
                bound[so] = b
            if external:
                for so, w in external.items():
                    bound.setdefault(so, w)

            changed = True
            while changed:
                changed = False
                for so, per_version in self._deps.items():
                    b = bound.get(so, -1)
                    for v in sorted(ver for ver in per_version if ver <= b):
                        for dep_so, dep_version in per_version[v]:
                            if dep_so == so:
                                continue  # precedence is implicit
                            if bound.get(dep_so, -1) < dep_version:
                                # v (and everything after) cannot be in the
                                # closure: cut this SO's watermark below v.
                                bound[so] = v - 1
                                changed = True
                                break
                        if bound[so] < v:
                            break
            return {so: b for so, b in bound.items() if so in self._labels}

    def snap_to_labels(self, watermarks: Mapping[str, int]) -> Dict[str, int]:
        """Snap each watermark down to the greatest persisted label <= it.

        Restore targets must be loadable versions; -1 means the initial
        (Connect-time) version 0 snapshot does not exist yet, which cannot
        happen in practice because Connect persists version 0 synchronously.
        """
        with self._lock:
            out: Dict[str, int] = {}
            for so, w in watermarks.items():
                labels = self._labels.get(so, [])
                i = bisect.bisect_right(labels, w)
                out[so] = labels[i - 1] if i > 0 else -1
            return out

    def rollback_targets(self, failed_so: str, surviving: int) -> Dict[str, int]:
        """Consistent prefix after ``failed_so`` lost every version > ``surviving``."""
        bound = self.recoverable_boundary({failed_so: surviving})
        return self.snap_to_labels(bound)
