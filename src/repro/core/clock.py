"""Injectable time + concurrency primitives (DESIGN.md §8).

Every blocking primitive the DSE stack uses — reading the clock, sleeping,
events, condition variables, locks held across waits, and background
threads — goes through a :class:`Clock` so the whole stack can run either
on the real OS scheduler (:class:`RealClock`, the default everywhere) or
under the deterministic simulation runtime (``repro.sim.SimScheduler``),
where time is virtual and a seeded scheduler picks every interleaving.

The contract a Clock implementation must satisfy:

* ``now()`` is monotone non-decreasing;
* ``sleep(d)`` returns no earlier than ``now()+d`` *in that clock's time*;
* ``event()`` / ``condition(lock)`` / ``lock()`` / ``rlock()`` return
  objects with the corresponding :mod:`threading` interfaces (``wait`` with
  optional timeout, ``set``/``clear``, ``notify``/``notify_all``, context
  management);
* ``spawn(fn)`` starts ``fn`` on an independent thread of control and
  returns a handle with ``join(timeout)`` and ``is_alive()``.

Code that never blocks while holding a lock may keep using plain
``threading.Lock`` (leaf locks); anything held across a wait, or waited on
directly, must come from the clock — a real lock held by a paused
simulation task would deadlock the cooperative scheduler.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class SpawnHandle:
    """Handle for a thread of control started via :meth:`Clock.spawn`."""

    def join(self, timeout: Optional[float] = None) -> None:
        raise NotImplementedError

    def is_alive(self) -> bool:
        raise NotImplementedError


class Clock:
    """Abstract time + blocking-primitive source (see module docstring)."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    def event(self):
        raise NotImplementedError

    def condition(self, lock=None):
        raise NotImplementedError

    def lock(self):
        raise NotImplementedError

    def rlock(self):
        raise NotImplementedError

    def spawn(self, fn: Callable[[], None], *, name: Optional[str] = None) -> SpawnHandle:
        raise NotImplementedError


class _ThreadHandle(SpawnHandle):
    def __init__(self, thread: threading.Thread) -> None:
        self._thread = thread

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    def is_alive(self) -> bool:
        return self._thread.is_alive()


class RealClock(Clock):
    """The production clock: OS time and :mod:`threading` primitives."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)

    def event(self) -> threading.Event:
        return threading.Event()

    def condition(self, lock=None) -> threading.Condition:
        return threading.Condition(lock)

    def lock(self) -> threading.Lock:
        return threading.Lock()

    def rlock(self) -> threading.RLock:
        return threading.RLock()

    def spawn(self, fn: Callable[[], None], *, name: Optional[str] = None) -> SpawnHandle:
        t = threading.Thread(target=fn, name=name, daemon=True)
        t.start()
        return _ThreadHandle(t)


#: Shared default instance — module-level so identity checks and dataclass
#: defaults are cheap; RealClock is stateless.
REAL_CLOCK = RealClock()
