"""Vertex / header / decision wire types for the DSE protocol.

A *vertex* on the recovery dependency graph is a recoverable point,
uniquely identified by (StateObject id, global failure counter ``world``,
local persistence counter ``version``) — the paper's :math:`A^x_y`.

Message *headers* carry the dependency set of the sending entity. A
StateObject-originated message carries exactly its current in-progress
vertex; an sthread-originated message carries the sthread's accumulated
dependency set (paper §4.2, Instrumentation Protocol).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, Mapping, Optional, Tuple


@dataclass(frozen=True, order=True)
class Vertex:
    """A recoverable point :math:`A^{world}_{version}` on the dependency graph."""

    so_id: str
    world: int
    version: int

    def to_json(self) -> list:
        return [self.so_id, self.world, self.version]

    @staticmethod
    def from_json(obj: Iterable) -> "Vertex":
        so_id, world, version = obj
        return Vertex(str(so_id), int(world), int(version))

    def __repr__(self) -> str:  # A_y^x notation from the paper
        return f"{self.so_id}_{self.version}^{self.world}"


@dataclass(frozen=True)
class Header:
    """Opaque libDSE message header (paper Table 2).

    ``deps`` is the set of vertices the receiver will depend on if it
    consumes this message. StateObject sends produce a single-vertex set;
    sthread sends may carry many.
    """

    deps: FrozenSet[Vertex] = frozenset()

    def encode(self) -> bytes:
        return json.dumps(sorted(v.to_json() for v in self.deps)).encode()

    @staticmethod
    def decode(raw: bytes) -> "Header":
        return Header(frozenset(Vertex.from_json(o) for o in json.loads(raw.decode())))

    def merge(self, other: "Header") -> "Header":
        return Header(self.deps | other.deps)

    @staticmethod
    def of(*vertices: Vertex) -> "Header":
        return Header(frozenset(vertices))

    def max_version_for(self, exclude_so: Optional[str] = None) -> int:
        """Largest version watermark carried (commit ordering rule input)."""
        versions = [v.version for v in self.deps if v.so_id != exclude_so]
        return max(versions, default=-1)


@dataclass(frozen=True)
class RollbackDecision:
    """A coordinator rollback decision, synchronously persisted (paper §4.3).

    ``fsn``      — failure sequence number; becomes the new ``world``.
    ``targets``  — per-SO version watermark to restore to (surviving prefix).
    ``lost``     — per-SO version watermark *above which* vertices are lost
                   (== targets; kept explicit for skip-rollback checks).
    ``failed``   — the SO whose failure triggered this decision.
    """

    fsn: int
    failed: str
    targets: Mapping[str, int] = field(default_factory=dict)

    def invalidates(self, v: Vertex) -> bool:
        """True iff this decision rolled back vertex ``v``."""
        if v.world >= self.fsn:
            return False  # v was created after (or by) this recovery
        target = self.targets.get(v.so_id)
        if target is None:
            return False  # SO not a participant of this rollback
        return v.version > target

    def to_json(self) -> dict:
        return {"fsn": self.fsn, "failed": self.failed, "targets": dict(self.targets)}

    @staticmethod
    def from_json(obj: dict) -> "RollbackDecision":
        return RollbackDecision(
            fsn=int(obj["fsn"]),
            failed=str(obj["failed"]),
            targets={str(k): int(v) for k, v in obj["targets"].items()},
        )


def vertex_rolled_back(v: Vertex, decisions: Iterable[RollbackDecision]) -> bool:
    """True iff any decision in ``decisions`` invalidates ``v``."""
    return any(d.invalidates(v) for d in decisions)


@dataclass
class PersistReport:
    """StateObject → coordinator report: vertex became durable with deps."""

    vertex: Vertex
    deps: Tuple[Vertex, ...]

    def to_json(self) -> dict:
        return {"v": self.vertex.to_json(), "deps": [d.to_json() for d in self.deps]}

    @staticmethod
    def from_json(obj: dict) -> "PersistReport":
        return PersistReport(
            vertex=Vertex.from_json(obj["v"]),
            deps=tuple(Vertex.from_json(d) for d in obj["deps"]),
        )


def encode_metadata(world: int, version: int, deps: Iterable[Vertex], user: bytes = b"") -> bytes:
    """Serialize the dependency-graph fragment persisted with each version.

    The paper (§4.3, Finding Boundaries) persists graph fragments inside each
    StateObject via the ``metadata`` argument of ``Persist`` — this is the
    distributed point of truth that a recovering coordinator reassembles.
    ``user`` carries service-specific metadata piggybacked on the same blob.
    """
    blob = {
        "world": world,
        "version": version,
        "deps": [d.to_json() for d in deps],
        "user": user.hex(),
    }
    return json.dumps(blob).encode()


def decode_metadata(raw: bytes) -> Tuple[int, int, Tuple[Vertex, ...], bytes]:
    obj = json.loads(raw.decode())
    return (
        int(obj["world"]),
        int(obj["version"]),
        tuple(Vertex.from_json(d) for d in obj["deps"]),
        bytes.fromhex(obj.get("user", "")),
    )
