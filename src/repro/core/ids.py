"""Vertex / header / decision wire types for the DSE protocol.

A *vertex* on the recovery dependency graph is a recoverable point,
uniquely identified by (StateObject id, global failure counter ``world``,
local persistence counter ``version``) — the paper's :math:`A^x_y`.

Message *headers* carry the dependency set of the sending entity. A
StateObject-originated message carries exactly its current in-progress
vertex; an sthread-originated message carries the sthread's accumulated
dependency set (paper §4.2, Instrumentation Protocol).

Wire encoding (DESIGN.md §9): every protocol blob is struct-packed binary
with per-blob so_id interning — first byte ``0xD5``, then a kind byte, a
string table, and varint-packed vertices. JSON is kept as the *versioned
fallback*: blobs whose first byte is ``{`` or ``[`` are legacy JSON and
decode transparently (old persisted metadata, old coordinator logs).
"""
from __future__ import annotations

import bisect
import json
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Mapping, Optional, Sequence, Tuple


# --------------------------------------------------------------------------- #
# binary primitives: varints + per-blob string interning                      #
# --------------------------------------------------------------------------- #
WIRE_MAGIC = 0xD5  # cannot start a JSON document (``{`` = 0x7B, ``[`` = 0x5B)

K_HEADER = 1
K_METADATA = 2
K_REPORT = 3  # legacy report body (no seq field) — read-only fallback
K_REPORTS = 4  # legacy batch — read-only fallback
K_DECISION = 5
K_DECISIONS = 6
K_BOUNDARY = 7
#: report bodies gained a per-incarnation flush ``seq`` (PR 4); per the
#: versioning rule (DESIGN.md §9) the layout change takes a NEW kind byte —
#: writers emit v2, readers accept both so pre-seq blobs stay decodable.
K_REPORT2 = 8
K_REPORTS2 = 9
#: decision bodies gained per-SO ``lost`` watermarks (PR 5, snapshot
#: retirement rule — DESIGN.md §11); same versioning rule: new kind bytes,
#: readers accept the pre-lost kinds with ``lost={}`` (never retirable).
K_DECISION2 = 10
K_DECISIONS2 = 11
#: reserved by repro.store (DESIGN.md §11): coordinator snapshot + manifest
K_SNAPSHOT = 12
K_MANIFEST = 13


def _w_uvarint(out: bytearray, n: int) -> None:
    if n < 0:
        raise ValueError(f"uvarint cannot encode negative {n}")
    while n >= 0x80:
        out.append((n & 0x7F) | 0x80)
        n >>= 7
    out.append(n)


def _r_uvarint(buf: bytes, i: int) -> Tuple[int, int]:
    shift = 0
    n = 0
    while True:
        if i >= len(buf):
            raise ValueError(f"truncated blob: varint runs past end at byte {i}")
        b = buf[i]
        i += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, i
        shift += 7
        if shift > 70:
            raise ValueError("malformed blob: varint wider than 10 bytes")


def _r_bytes(buf: bytes, i: int, n: int) -> Tuple[bytes, int]:
    """Bounds-checked slice: a truncated buffer must raise, never silently
    yield a shortened string/user-bytes payload."""
    if n < 0 or i + n > len(buf):
        raise ValueError(
            f"truncated blob: need {n} bytes at {i}, have {len(buf) - i}"
        )
    return buf[i : i + n], i + n


def _str_at(strings: List[str], idx: int) -> str:
    if idx >= len(strings):
        raise ValueError(
            f"malformed blob: string index {idx} out of table of {len(strings)}"
        )
    return strings[idx]


def _w_svarint(out: bytearray, n: int) -> None:
    # zigzag: small negatives (watermark -1) stay 1 byte
    _w_uvarint(out, (n << 1) if n >= 0 else ((-n) << 1) - 1)


def _r_svarint(buf: bytes, i: int) -> Tuple[int, int]:
    z, i = _r_uvarint(buf, i)
    return (z >> 1) ^ -(z & 1), i


class _StrTable:
    """Encode-side so_id interning: each distinct string is written once in
    the blob's string table and referenced by index everywhere else."""

    def __init__(self) -> None:
        self._idx: Dict[str, int] = {}
        self.strings: List[str] = []

    def index(self, s: str) -> int:
        i = self._idx.get(s)
        if i is None:
            i = self._idx[s] = len(self.strings)
            self.strings.append(s)
        return i

    def write(self, out: bytearray) -> None:
        _w_uvarint(out, len(self.strings))
        for s in self.strings:
            raw = s.encode("utf-8")
            _w_uvarint(out, len(raw))
            out += raw

    @staticmethod
    def read(buf: bytes, i: int) -> Tuple[List[str], int]:
        n, i = _r_uvarint(buf, i)
        strings: List[str] = []
        for _ in range(n):
            ln, i = _r_uvarint(buf, i)
            raw, i = _r_bytes(buf, i, ln)
            strings.append(raw.decode("utf-8"))
        return strings, i


def _begin(kind: int) -> Tuple[bytearray, bytearray, _StrTable]:
    """Returns (prefix, body, table); finish with ``_finish``. The table is
    written between prefix and body so decoders can resolve indices."""
    return bytearray((WIRE_MAGIC, kind)), bytearray(), _StrTable()


def _finish(prefix: bytearray, body: bytearray, tab: _StrTable) -> bytes:
    tab.write(prefix)
    return bytes(prefix + body)


def _expect(raw: bytes, kind: int) -> Tuple[List[str], int]:
    if len(raw) < 2 or raw[0] != WIRE_MAGIC or raw[1] != kind:
        raise ValueError(f"not a binary kind={kind} blob (starts {raw[:2]!r})")
    return _StrTable.read(raw, 2)


@dataclass(frozen=True, order=True)
class Vertex:
    """A recoverable point :math:`A^{world}_{version}` on the dependency graph."""

    so_id: str
    world: int
    version: int

    def to_json(self) -> list:
        return [self.so_id, self.world, self.version]

    @staticmethod
    def from_json(obj: Iterable) -> "Vertex":
        so_id, world, version = obj
        return Vertex(str(so_id), int(world), int(version))

    def __repr__(self) -> str:  # A_y^x notation from the paper
        return f"{self.so_id}_{self.version}^{self.world}"


def _write_vertex(out: bytearray, tab: _StrTable, v: Vertex) -> None:
    _w_uvarint(out, tab.index(v.so_id))
    _w_svarint(out, v.world)
    _w_svarint(out, v.version)


def _read_vertex(buf: bytes, i: int, strings: List[str]) -> Tuple[Vertex, int]:
    si, i = _r_uvarint(buf, i)
    world, i = _r_svarint(buf, i)
    version, i = _r_svarint(buf, i)
    return Vertex(_str_at(strings, si), world, version), i


@dataclass(frozen=True)
class Header:
    """Opaque libDSE message header (paper Table 2).

    ``deps`` is the set of vertices the receiver will depend on if it
    consumes this message. StateObject sends produce a single-vertex set;
    sthread sends may carry many.
    """

    deps: FrozenSet[Vertex] = frozenset()

    def encode(self) -> bytes:
        prefix, body, tab = _begin(K_HEADER)
        _w_uvarint(body, len(self.deps))
        for v in sorted(self.deps):  # canonical order: equal headers, equal bytes
            _write_vertex(body, tab, v)
        return _finish(prefix, body, tab)

    @staticmethod
    def decode(raw: bytes) -> "Header":
        if raw[:1] == b"[":  # legacy JSON header
            return Header(frozenset(Vertex.from_json(o) for o in json.loads(raw.decode())))
        strings, i = _expect(raw, K_HEADER)
        n, i = _r_uvarint(raw, i)
        deps = []
        for _ in range(n):
            v, i = _read_vertex(raw, i, strings)
            deps.append(v)
        return Header(frozenset(deps))

    def merge(self, other: "Header") -> "Header":
        return Header(self.deps | other.deps)

    @staticmethod
    def of(*vertices: Vertex) -> "Header":
        return Header(frozenset(vertices))

    def max_version_for(self, exclude_so: Optional[str] = None) -> int:
        """Largest version watermark carried (commit ordering rule input)."""
        versions = [v.version for v in self.deps if v.so_id != exclude_so]
        return max(versions, default=-1)


@dataclass(frozen=True)
class RollbackDecision:
    """A coordinator rollback decision, synchronously persisted (paper §4.3).

    ``fsn``      — failure sequence number; becomes the new ``world``.
    ``targets``  — per-SO version watermark to restore to (surviving prefix).
    ``failed``   — the SO whose failure triggered this decision.
    ``lost``     — per-SO top *persisted* label at decision time: every
                   vertex this decision can ever invalidate has version in
                   ``(targets[so], lost[so]]``. Once the exposure floor of
                   every target passes its ``lost`` watermark, the decision
                   can never match anything again and the snapshot compactor
                   retires it (DESIGN.md §11). Empty => unknown (a legacy
                   decision): never retired.
    """

    fsn: int
    failed: str
    targets: Mapping[str, int] = field(default_factory=dict)
    lost: Mapping[str, int] = field(default_factory=dict)

    def invalidates(self, v: Vertex) -> bool:
        """True iff this decision rolled back vertex ``v``."""
        if v.world >= self.fsn:
            return False  # v was created after (or by) this recovery
        target = self.targets.get(v.so_id)
        if target is None:
            return False  # SO not a participant of this rollback
        return v.version > target

    def to_json(self) -> dict:
        out = {"fsn": self.fsn, "failed": self.failed, "targets": dict(self.targets)}
        if self.lost:
            out["lost"] = dict(self.lost)
        return out

    @staticmethod
    def from_json(obj: dict) -> "RollbackDecision":
        return RollbackDecision(
            fsn=int(obj["fsn"]),
            failed=str(obj["failed"]),
            targets={str(k): int(v) for k, v in obj["targets"].items()},
            lost={str(k): int(v) for k, v in obj.get("lost", {}).items()},
        )


def vertex_rolled_back(v: Vertex, decisions: Iterable[RollbackDecision]) -> bool:
    """True iff any decision in ``decisions`` invalidates ``v``."""
    return any(d.invalidates(v) for d in decisions)


class DecisionIndex:
    """Compacted per-SO invalidation index over a set of rollback decisions.

    ``vertex_rolled_back`` scans every decision per vertex — O(failures) on
    the message hot path. This index compacts the decision list into, per
    SO, the fsns that target it plus suffix-minimum targets, making
    ``invalidates`` O(log failures):

        v invalidated  ⇔  ∃d: d.fsn > v.world ∧ v.version > d.targets[v.so_id]
                       ⇔  v.version > min{ d.targets[so] : d.fsn > v.world }

    and the suffix minimum over fsn-sorted targets answers the RHS with one
    bisect. Soundness: exact by construction — see DESIGN.md §9.

    Not internally locked: callers mutate/read under their own mutex (the
    coordinator lock / the runtime ``_mu``), matching the lists it replaces.
    """

    __slots__ = ("_fsns", "_targets", "_sufmin", "max_fsn", "count")

    def __init__(self, decisions: Iterable[RollbackDecision] = ()) -> None:
        # so_id -> parallel fsn-sorted lists
        self._fsns: Dict[str, List[int]] = {}
        self._targets: Dict[str, List[int]] = {}
        self._sufmin: Dict[str, List[int]] = {}
        self.max_fsn = 0
        self.count = 0
        for d in decisions:
            self.add(d)

    def add(self, d: RollbackDecision) -> None:
        self.max_fsn = max(self.max_fsn, d.fsn)
        self.count += 1
        for so, target in d.targets.items():
            fsns = self._fsns.setdefault(so, [])
            targets = self._targets.setdefault(so, [])
            i = bisect.bisect_right(fsns, d.fsn)
            fsns.insert(i, d.fsn)
            targets.insert(i, int(target))
            # rebuild the suffix minima for this SO (appends are rare — one
            # per cluster failure — while lookups are per-message)
            suf: List[int] = [0] * len(targets)
            m = targets[-1]
            for j in range(len(targets) - 1, -1, -1):
                m = min(m, targets[j])
                suf[j] = m
            self._sufmin[so] = suf

    def invalidates(self, v: Vertex) -> bool:
        fsns = self._fsns.get(v.so_id)
        if not fsns:
            return False
        i = bisect.bisect_right(fsns, v.world)  # first decision with fsn > world
        if i >= len(fsns):
            return False
        return v.version > self._sufmin[v.so_id][i]

    def any_invalid(self, deps: Iterable[Vertex]) -> bool:
        return any(self.invalidates(dep) for dep in deps)


@dataclass
class PersistReport:
    """StateObject → coordinator report: vertex became durable with deps.

    ``seq`` is a per-incarnation flush sequence number (-1 = unknown, e.g. a
    Connect/fragment-resend report rebuilt from disk). The coordinator drops
    a report whose ``(world, seq)`` it has already processed for this SO —
    the requeue path can legitimately resend a report whose original
    delivery succeeded after its RPC timed out (at-least-once wire).
    """

    vertex: Vertex
    deps: Tuple[Vertex, ...]
    seq: int = -1

    def to_json(self) -> dict:
        out = {"v": self.vertex.to_json(), "deps": [d.to_json() for d in self.deps]}
        if self.seq >= 0:
            out["seq"] = self.seq
        return out

    @staticmethod
    def from_json(obj: dict) -> "PersistReport":
        return PersistReport(
            vertex=Vertex.from_json(obj["v"]),
            deps=tuple(Vertex.from_json(d) for d in obj["deps"]),
            seq=int(obj.get("seq", -1)),
        )


# --------------------------------------------------------------------------- #
# binary wire codec (DESIGN.md §9)                                            #
# --------------------------------------------------------------------------- #
def _write_report_body(body: bytearray, tab: _StrTable, r: PersistReport) -> None:
    _write_vertex(body, tab, r.vertex)
    _w_svarint(body, r.seq)
    _w_uvarint(body, len(r.deps))
    for d in r.deps:
        _write_vertex(body, tab, d)


def _read_report_body(
    raw: bytes, i: int, strings: List[str], with_seq: bool
) -> Tuple[PersistReport, int]:
    vertex, i = _read_vertex(raw, i, strings)
    seq = -1
    if with_seq:
        seq, i = _r_svarint(raw, i)
    n, i = _r_uvarint(raw, i)
    deps = []
    for _ in range(n):
        d, i = _read_vertex(raw, i, strings)
        deps.append(d)
    return PersistReport(vertex, tuple(deps), seq=seq), i


def _expect_either(raw: bytes, kind_v2: int, kind_legacy: int) -> Tuple[List[str], int, bool]:
    """(strings, offset, is_v2) for a v2-or-legacy blob (reports: v2 adds
    the seq field; decisions: v2 adds the lost watermarks)."""
    if len(raw) >= 2 and raw[0] == WIRE_MAGIC and raw[1] == kind_legacy:
        strings, i = _StrTable.read(raw, 2)
        return strings, i, False
    strings, i = _expect(raw, kind_v2)
    return strings, i, True


def encode_report(r: PersistReport) -> bytes:
    prefix, body, tab = _begin(K_REPORT2)
    _write_report_body(body, tab, r)
    return _finish(prefix, body, tab)


def decode_report(raw: bytes) -> PersistReport:
    strings, i, with_seq = _expect_either(raw, K_REPORT2, K_REPORT)
    r, _ = _read_report_body(raw, i, strings, with_seq)
    return r


def encode_reports(reports: Sequence[PersistReport]) -> bytes:
    """Batch encoding with ONE shared string table: a fragment resend of a
    whole SO history names each dep SO once, not once per vertex."""
    prefix, body, tab = _begin(K_REPORTS2)
    _w_uvarint(body, len(reports))
    for r in reports:
        _write_report_body(body, tab, r)
    return _finish(prefix, body, tab)


def decode_reports(raw: bytes) -> List[PersistReport]:
    strings, i, with_seq = _expect_either(raw, K_REPORTS2, K_REPORTS)
    n, i = _r_uvarint(raw, i)
    out: List[PersistReport] = []
    for _ in range(n):
        r, i = _read_report_body(raw, i, strings, with_seq)
        out.append(r)
    return out


def _write_watermarks(body: bytearray, tab: _StrTable, wm: Mapping[str, int]) -> None:
    _w_uvarint(body, len(wm))
    for so, t in sorted(wm.items()):
        _w_uvarint(body, tab.index(so))
        _w_svarint(body, t)


def _read_watermarks(raw: bytes, i: int, strings: List[str]) -> Tuple[Dict[str, int], int]:
    n, i = _r_uvarint(raw, i)
    out: Dict[str, int] = {}
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        t, i = _r_svarint(raw, i)
        out[_str_at(strings, si)] = t
    return out, i


def _write_decision_body(body: bytearray, tab: _StrTable, d: RollbackDecision) -> None:
    _w_uvarint(body, d.fsn)
    _w_uvarint(body, tab.index(d.failed))
    _write_watermarks(body, tab, d.targets)
    _write_watermarks(body, tab, d.lost)


def _read_decision_body(
    raw: bytes, i: int, strings: List[str], with_lost: bool = True
) -> Tuple[RollbackDecision, int]:
    fsn, i = _r_uvarint(raw, i)
    fi, i = _r_uvarint(raw, i)
    targets, i = _read_watermarks(raw, i, strings)
    lost: Dict[str, int] = {}
    if with_lost:
        lost, i = _read_watermarks(raw, i, strings)
    return (
        RollbackDecision(fsn=fsn, failed=_str_at(strings, fi), targets=targets, lost=lost),
        i,
    )


def encode_decision(d: RollbackDecision) -> bytes:
    prefix, body, tab = _begin(K_DECISION2)
    _write_decision_body(body, tab, d)
    return _finish(prefix, body, tab)


def decode_decision(raw: bytes) -> RollbackDecision:
    strings, i, with_lost = _expect_either(raw, K_DECISION2, K_DECISION)
    d, _ = _read_decision_body(raw, i, strings, with_lost)
    return d


def encode_decisions(decisions: Sequence[RollbackDecision]) -> bytes:
    prefix, body, tab = _begin(K_DECISIONS2)
    _w_uvarint(body, len(decisions))
    for d in decisions:
        _write_decision_body(body, tab, d)
    return _finish(prefix, body, tab)


def decode_decisions(raw: bytes) -> List[RollbackDecision]:
    strings, i, with_lost = _expect_either(raw, K_DECISIONS2, K_DECISIONS)
    n, i = _r_uvarint(raw, i)
    out: List[RollbackDecision] = []
    for _ in range(n):
        d, i = _read_decision_body(raw, i, strings, with_lost)
        out.append(d)
    return out


def encode_boundary(boundary: Mapping[str, int]) -> bytes:
    prefix, body, tab = _begin(K_BOUNDARY)
    _w_uvarint(body, len(boundary))
    for so, w in sorted(boundary.items()):
        _w_uvarint(body, tab.index(so))
        _w_svarint(body, w)
    return _finish(prefix, body, tab)


def decode_boundary(raw: bytes) -> Dict[str, int]:
    strings, i = _expect(raw, K_BOUNDARY)
    n, i = _r_uvarint(raw, i)
    out: Dict[str, int] = {}
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        w, i = _r_svarint(raw, i)
        out[_str_at(strings, si)] = w
    return out


def encode_metadata(world: int, version: int, deps: Iterable[Vertex], user: bytes = b"") -> bytes:
    """Serialize the dependency-graph fragment persisted with each version.

    The paper (§4.3, Finding Boundaries) persists graph fragments inside each
    StateObject via the ``metadata`` argument of ``Persist`` — this is the
    distributed point of truth that a recovering coordinator reassembles.
    ``user`` carries service-specific metadata piggybacked on the same blob
    (as raw bytes; the legacy JSON format hex-doubled them).
    """
    prefix, body, tab = _begin(K_METADATA)
    _w_svarint(body, world)
    _w_svarint(body, version)
    deps = list(deps)
    _w_uvarint(body, len(deps))
    for d in deps:
        _write_vertex(body, tab, d)
    _w_uvarint(body, len(user))
    body += user
    return _finish(prefix, body, tab)


def encode_metadata_json(world: int, version: int, deps: Iterable[Vertex], user: bytes = b"") -> bytes:
    """Legacy (pre-binary) metadata format, retained as the versioned
    fallback writer so tests can pin old-blob compatibility forever."""
    blob = {
        "world": world,
        "version": version,
        "deps": [d.to_json() for d in deps],
        "user": user.hex(),
    }
    return json.dumps(blob).encode()


def decode_metadata(raw: bytes) -> Tuple[int, int, Tuple[Vertex, ...], bytes]:
    if raw[:1] == b"{":  # legacy JSON blob persisted by an older build
        obj = json.loads(raw.decode())
        return (
            int(obj["world"]),
            int(obj["version"]),
            tuple(Vertex.from_json(d) for d in obj["deps"]),
            bytes.fromhex(obj.get("user", "")),
        )
    strings, i = _expect(raw, K_METADATA)
    world, i = _r_svarint(raw, i)
    version, i = _r_svarint(raw, i)
    n, i = _r_uvarint(raw, i)
    deps = []
    for _ in range(n):
        d, i = _read_vertex(raw, i, strings)
        deps.append(d)
    ulen, i = _r_uvarint(raw, i)
    user, i = _r_bytes(raw, i, ulen)
    return world, version, tuple(deps), bytes(user)
