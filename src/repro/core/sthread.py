"""sthreads: lightweight speculative threads of execution (paper §3.2).

An sthread encodes the speculative state of its parent StateObject at
creation time as a dependency *set*; it does not own graph vertices.
sthreads interact with every participant — including the parent — only via
instrumented message passing (``Receive``/``Send``) and can ``Barrier()``
to wait until everything they observed is non-speculative.
"""
from __future__ import annotations

import threading
from typing import Optional, Set, TYPE_CHECKING

from .ids import Header, Vertex

if TYPE_CHECKING:  # pragma: no cover
    from .runtime import DSERuntime


class RolledBackError(Exception):
    """The speculative state this entity derives from has been rolled back."""


class DelayMessage(Exception):
    """Message is from a future failure epoch; redeliver after catching up
    (Recovery Partition Rule, paper Def 4.3)."""


class SThread:
    def __init__(self, runtime: "DSERuntime", deps: Set[Vertex]) -> None:
        self._runtime = runtime
        self._deps: Set[Vertex] = set(deps)
        self._lock = threading.Lock()
        self._rolled_back = False

    # ------------------------------------------------------------------ #
    def _check_self(self) -> None:
        if self._rolled_back or self._runtime.any_invalid(self._deps):
            self._rolled_back = True
            raise RolledBackError("sthread derives from rolled-back state")

    def Receive(self, header: Header) -> bool:
        """Consume a message header. False => discard the message.
        Raises :class:`RolledBackError` if this sthread itself is stale."""
        self._check_self()
        status = self._runtime.classify_header(header)
        if status == "delay":
            raise DelayMessage()
        if status == "discard":
            return False
        with self._lock:
            self._deps |= header.deps
        return True

    def Send(self) -> Header:
        self._check_self()
        with self._lock:
            return Header(frozenset(self._deps))

    def Barrier(self, timeout: Optional[float] = None) -> None:
        """Block until all observed state is non-speculative (paper §3.2).
        Clears the dependency set afterwards to bound growth."""
        self._check_self()
        with self._lock:
            deps = frozenset(self._deps)
        self._runtime.barrier(deps, timeout=timeout)
        self._check_self()
        with self._lock:
            self._deps.clear()

    @property
    def deps(self) -> Set[Vertex]:
        with self._lock:
            return set(self._deps)
