"""Epoch protection for actions (paper §5.1, Synchronization).

libDSE executes every *action* under a shared lock and every
Persist/Restore under an exclusive lock, so actions never interleave with
persistence or recovery. The paper uses biased reader-writer locking
(BRAVO-style) for multicore scalability; under CPython the bias table's
benefit is bounded by the GIL, so we implement a writer-preferring
reader-writer lock with a striped reader-count fast path that preserves the
algorithmic shape (readers touch only their stripe in the common case and
fall back to the slow path when a writer has raised the bias-revoked flag).
"""
from __future__ import annotations

import threading
from typing import List

from .clock import Clock, REAL_CLOCK


_NUM_STRIPES = 16


class EpochRWLock:
    """Writer-preferring reader-writer lock with striped reader fast path.

    Blocking waits go through the injected ``clock`` (DESIGN.md §8) so the
    lock works under both the OS scheduler and deterministic simulation;
    the stripe locks are leaf locks (never held across a wait) and stay
    plain ``threading.Lock``.
    """

    def __init__(self, clock: Clock = REAL_CLOCK) -> None:
        self._mutex = clock.lock()
        self._readers_cv = clock.condition(self._mutex)
        self._writer_cv = clock.condition(self._mutex)
        self._stripe_locks: List[threading.Lock] = [threading.Lock() for _ in range(_NUM_STRIPES)]
        self._stripe_counts: List[int] = [0] * _NUM_STRIPES
        self._writer_active = False
        self._writers_waiting = 0
        # When True, readers must take the slow path (bias revoked).
        self._bias_revoked = False

    # -- reader (action) side -------------------------------------------------
    def _stripe(self) -> int:
        return threading.get_ident() % _NUM_STRIPES

    def acquire_shared(self) -> None:
        s = self._stripe()
        if not self._bias_revoked:
            # Fast path: bump our stripe, then re-check the flag. If a writer
            # arrived concurrently we undo and fall through to the slow path.
            with self._stripe_locks[s]:
                self._stripe_counts[s] += 1
            if not self._bias_revoked:
                return
            with self._stripe_locks[s]:
                self._stripe_counts[s] -= 1
            with self._mutex:
                self._writer_cv.notify_all()
        with self._mutex:
            while self._writer_active or self._writers_waiting > 0:
                self._readers_cv.wait()
            with self._stripe_locks[s]:
                self._stripe_counts[s] += 1

    def release_shared(self) -> None:
        s = self._stripe()
        with self._stripe_locks[s]:
            self._stripe_counts[s] -= 1
            stripe_drained = self._stripe_counts[s] == 0
        # Wake the writer only when this stripe drained to zero: the LAST
        # release on any stripe always hits zero, so the writer (which
        # re-counts all stripes on each wakeup) cannot miss the global-zero
        # transition — and intermediate releases stay off the mutex. The
        # flag read is racy by design: under a total instruction order (the
        # GIL), a release that misses a concurrent writer's flag-set
        # happened-before the writer's reader count, which then sees the
        # decrement.
        if stripe_drained and self._bias_revoked:
            with self._mutex:
                self._writer_cv.notify_all()

    # -- writer (persist/restore) side ----------------------------------------
    def _readers_total(self) -> int:
        total = 0
        for i in range(_NUM_STRIPES):
            with self._stripe_locks[i]:
                total += self._stripe_counts[i]
        return total

    def acquire_exclusive(self) -> None:
        with self._mutex:
            self._writers_waiting += 1
            self._bias_revoked = True
            # One combined predicate, no poll timeout: release_shared
            # notifies whenever a stripe drains to zero (covering the last
            # reader's exit) and release_exclusive notifies the next writer.
            # writer_active must be re-checked on every wakeup — two writers
            # can both be parked waiting for readers, and only one may win.
            while self._writer_active or self._readers_total() > 0:
                self._writer_cv.wait()
            self._writer_active = True
            self._writers_waiting -= 1

    def release_exclusive(self) -> None:
        with self._mutex:
            self._writer_active = False
            if self._writers_waiting == 0:
                self._bias_revoked = False
                self._readers_cv.notify_all()
            else:
                self._writer_cv.notify_all()

    # -- context helpers -------------------------------------------------------
    class _Shared:
        def __init__(self, lock: "EpochRWLock") -> None:
            self._lock = lock

        def __enter__(self) -> None:
            self._lock.acquire_shared()

        def __exit__(self, *exc) -> None:
            self._lock.release_shared()

    class _Exclusive:
        def __init__(self, lock: "EpochRWLock") -> None:
            self._lock = lock

        def __enter__(self) -> None:
            self._lock.acquire_exclusive()

        def __exit__(self, *exc) -> None:
            self._lock.release_exclusive()

    def shared(self) -> "EpochRWLock._Shared":
        return EpochRWLock._Shared(self)

    def exclusive(self) -> "EpochRWLock._Exclusive":
        return EpochRWLock._Exclusive(self)
