"""Speculative serving loop: decode sessions as StateObjects.

The serving counterpart of train/loop.py. Session state (generated tokens +
cursor) is durable-by-DSE: the KV cache is *derived* state — on restore the
session replays its surviving token prefix through ``prefill`` to rebuild
the cache (cheap relative to the failure rate, exactly the paper's
trade). Responses stream to clients only behind speculation barriers.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import LocalCluster, StateObject, VersionStore
from ..models import cache_descs, decode_step, forward
from ..models.config import ModelConfig
from ..models.params import is_desc


class DecodeSessionStateObject(StateObject):
    """Tokens + cursor are the durable truth; the KV cache is derived."""

    def __init__(self, root: Path, cfg: ModelConfig, params, max_len: int = 64,
                 extras: Optional[dict] = None) -> None:
        super().__init__()
        self.store = VersionStore(root)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.extras = extras or {}
        self.tokens: List[int] = []
        self._cache = self._empty_cache()
        self._step = jax.jit(
            lambda p, c, t, i: decode_step(cfg, p, c, t, i, extras=self.extras)
        )

    def _empty_cache(self):
        return jax.tree_util.tree_map(
            lambda d: jnp.zeros(d.shape, jnp.float32),
            cache_descs(self.cfg, batch=1, max_len=self.max_len),
            is_leaf=is_desc,
        )

    def _rebuild_cache(self) -> None:
        """Replay surviving tokens to reconstruct the derived KV cache."""
        self._cache = self._empty_cache()
        tok = jnp.zeros((1, 1), jnp.int32)
        for i, t in enumerate([0] + self.tokens[:-1] if self.tokens else []):
            _, self._cache = self._step(
                self.params, self._cache,
                jnp.asarray([[t]], jnp.int32), jnp.asarray(i, jnp.int32),
            )

    # -- persistence -----------------------------------------------------
    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        payload = np.asarray(self.tokens, np.int32).tobytes()

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        self.tokens = list(np.frombuffer(payload, np.int32))
        self._rebuild_cache()
        return meta

    def ListVersions(self):
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        self.tokens = []
        self._cache = self._empty_cache()

    # -- service API -------------------------------------------------------
    def generate(self, n: int) -> Optional[List[int]]:
        """Speculatively decode ``n`` tokens (one action per token)."""
        out = []
        for _ in range(n):
            if not self.StartAction(None):
                return None
            idx = len(self.tokens)
            if idx >= self.max_len:
                self.EndAction()
                break
            prev = self.tokens[-1] if self.tokens else 0
            logits, self._cache = self._step(
                self.params, self._cache,
                jnp.asarray([[prev]], jnp.int32), jnp.asarray(idx, jnp.int32),
            )
            t = int(jnp.argmax(logits[0, 0, : self.cfg.vocab_size]))
            self.tokens.append(t)
            out.append(t)
            self.EndAction()
        return out

    def stream_durable(self, timeout: float = 30.0) -> Optional[List[int]]:
        """Barrier-gated export: only non-speculative tokens leave."""
        if not self.StartAction(None):
            return None
        if not self.wait_durable(timeout=timeout):
            return None
        out = list(self.tokens)
        self.EndAction()
        return out


@dataclass
class ServeRunResult:
    tokens_generated: int
    durable_tokens: List[int]
    rollbacks: int


def run_speculative_serving(
    root: Path,
    cfg: ModelConfig,
    params,
    *,
    n_tokens: int = 16,
    kill_at: Optional[int] = None,
    group_commit_interval: float = 0.02,
    extras: Optional[dict] = None,
) -> ServeRunResult:
    with LocalCluster(root, group_commit_interval=group_commit_interval) as cluster:
        mk = lambda: DecodeSessionStateObject(
            Path(root) / "sess", cfg, params, max_len=max(64, n_tokens + 1),
            extras=extras,
        )
        sess = cluster.add("session", mk)
        rollbacks = 0
        produced = 0
        while produced < n_tokens:
            sess = cluster.get("session")
            before = len(sess.tokens)
            out = sess.generate(min(4, n_tokens - produced))
            if out is None:
                cluster.refresh_all()
                continue
            produced = len(sess.tokens)
            if kill_at is not None and produced >= kill_at:
                cluster.kill("session")
                kill_at = None
                rollbacks += 1
                produced = len(cluster.get("session").tokens)
        durable = cluster.get("session").stream_durable() or []
        return ServeRunResult(
            tokens_generated=produced,
            durable_tokens=durable,
            rollbacks=rollbacks,
        )
