"""Resilient training loop under DSE — the paper's durable-execution
abstraction applied to a JAX training job (DESIGN.md §2).

The driver composes three StateObjects:
    data  (stream cursor)  --header-->  trainer  --header-->  metrics

Every train step runs SPECULATIVELY: persistence happens in the background
at the group-commit cadence; failures roll the affected components back to
the consistent prefix and the driver resumes from the trainer's restored
step (control flow is part of persisted state). Externally-visible metrics
are barrier-gated. With a deterministic data pipeline, a run with failures
produces bit-identical parameters to a failure-free run — that is the
determinism test in tests/test_training.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np

from ..checkpoint import DeltaCheckpointCodec, MetricsStateObject, TrainerStateObject
from ..core import DelayMessage, LocalCluster
from ..data import DataPipelineStateObject, SyntheticLMData
from ..models import init_params, param_descs
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw_init
from ..launch.steps import make_train_step


@dataclass
class TrainRunResult:
    steps_run: int
    final_step: int
    params_digest: str
    metrics: List[Tuple[int, float]]
    external_metrics: List[Tuple[int, float]]
    rollbacks: int
    checkpoint_bytes: int


def run_resilient_training(
    root: Path,
    cfg: ModelConfig,
    *,
    steps: int = 20,
    global_batch: int = 4,
    seq_len: int = 16,
    kill_trainer_at: Optional[int] = None,
    kill_data_at: Optional[int] = None,
    group_commit_interval: float = 0.02,
    use_delta_codec: bool = False,
    seed: int = 0,
    lr: float = 1e-3,
) -> TrainRunResult:
    data = SyntheticLMData(cfg.vocab_size, global_batch, seq_len, seed=seed)
    opt_cfg = AdamWConfig(lr=lr)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))

    def init_state():
        params = init_params(param_descs(cfg), jax.random.key(seed), dtype=jax.numpy.float32)
        return params, adamw_init(params)

    codec = DeltaCheckpointCodec(base_every=4) if use_delta_codec else None

    with LocalCluster(root, group_commit_interval=group_commit_interval) as cluster:
        data_so = cluster.add(
            "data", lambda: DataPipelineStateObject(Path(root) / "data", data)
        )
        trainer = cluster.add(
            "trainer",
            lambda: TrainerStateObject(Path(root) / "trainer", init_state, step_fn, codec=codec),
        )
        metrics = cluster.add("metrics", lambda: MetricsStateObject(Path(root) / "metrics"))

        rollbacks = 0
        steps_run = 0
        last_world = 0
        while True:
            trainer = cluster.get("trainer")
            data_so = cluster.get("data")
            metrics = cluster.get("metrics")
            if trainer.runtime.world > last_world:  # a recovery happened
                rollbacks += trainer.runtime.world - last_world
                last_world = trainer.runtime.world
            t_step = trainer.current_step()
            if t_step >= steps:
                break

            try:
                if data_so.peek_cursor() != t_step:
                    data_so.seek(t_step)  # resync after rollback/restart
                    # reconcile metrics: a rollback may have dropped records
                    # for steps the trainer's restored state still covers (the
                    # paper's conservative over-rollback, §5.3); re-record
                    # from the trainer's own persisted loss history.
                    snap = trainer.history_snapshot()
                    if snap is not None:
                        history, hh = snap
                        have = {s for s, _ in metrics.records}
                        for s, l in history:
                            if s not in have:
                                metrics.record(s, l, hh)

                out = data_so.next_batch()
                if out is None:
                    continue
                step, tokens, hdr = out
                res = trainer.train_on(step, tokens, hdr)
                if res is None:
                    # stale cross-epoch message: let the refresher deliver
                    # the decision instead of spinning
                    cluster.refresh_all()
                    continue
                if isinstance(res, tuple) and res[0] == "resync":
                    continue
                loss, thdr = res
                steps_run += 1
                metrics.record(step, loss, thdr)
            except DelayMessage:
                # cross-epoch message (Def 4.3): let lagging components apply
                # pending decisions, then retry the iteration.
                cluster.refresh_all()
                continue

            if kill_trainer_at is not None and step + 1 == kill_trainer_at:
                cluster.kill("trainer")
                kill_trainer_at = None  # counted via the world watermark
            if kill_data_at is not None and step + 1 == kill_data_at:
                cluster.kill("data")
                kill_data_at = None

        # force final durability, reconcile any metric dropped by a late
        # rollback (the refresher applies decisions asynchronously), then
        # export only non-speculative metrics
        trainer = cluster.get("trainer")
        metrics = cluster.get("metrics")
        trainer.runtime.maybe_persist(force=True)
        snap = trainer.history_snapshot()
        if snap is not None:
            history, hh = snap
            have = {s for s, _ in metrics.records}
            for s, l in history:
                if s not in have:
                    metrics.record(s, l, hh)
        external = metrics.flush_external()
        recorded = list(metrics.records)

        return TrainRunResult(
            steps_run=steps_run,
            final_step=trainer.current_step(),
            params_digest=trainer.params_digest(),
            metrics=recorded,
            external_metrics=external,
            rollbacks=rollbacks,
            checkpoint_bytes=trainer.bytes_written,
        )
