from .loop import TrainRunResult, run_resilient_training

__all__ = ["TrainRunResult", "run_resilient_training"]
