"""AdamW in pure JAX. Optimizer moments are fp32 and shard exactly like
their parameters (the spec tree is reused leaf-for-leaf), which is what
makes FSDP-style 2D sharding of optimizer state work for the 90B arch."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params, grads, state, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1
    gnorm_sq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
    )
    gnorm = jnp.sqrt(gnorm_sq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
