"""Int8 gradient compression with error feedback (distributed-optimization
trick for wire-bandwidth-bound data parallelism; beyond-paper, DESIGN.md §6).

Used around the DP all-reduce inside ``shard_map``: compress local grads to
int8 (per-tensor scale), all-reduce in int32, decompress, and carry the
quantization residual into the next step (error feedback keeps convergence).
Deterministic and fully jittable; tested in tests/test_training.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_gradients_int8(grads, error_feedback):
    """Returns (codes int8 tree, scales tree, new_residual tree)."""

    def enc(g, e):
        g = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(g))
        scale = jnp.maximum(amax, 1e-30) / 127.0
        codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        resid = g - codes.astype(jnp.float32) * scale
        return codes, scale, resid

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    enc_out = [enc(g, e) for g, e in zip(flat_g, flat_e)]
    codes = jax.tree_util.tree_unflatten(treedef, [o[0] for o in enc_out])
    scales = jax.tree_util.tree_unflatten(treedef, [o[1] for o in enc_out])
    resid = jax.tree_util.tree_unflatten(treedef, [o[2] for o in enc_out])
    return codes, scales, resid


def decompress_gradients_int8(codes, scales):
    return jax.tree_util.tree_map(
        lambda c, s: c.astype(jnp.float32) * s, codes, scales
    )
