from .adamw import adamw_init, adamw_update, AdamWConfig
from .compress import compress_gradients_int8, decompress_gradients_int8

__all__ = [
    "adamw_init", "adamw_update", "AdamWConfig",
    "compress_gradients_int8", "decompress_gradients_int8",
]
