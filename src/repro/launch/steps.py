"""Step-function builders shared by the dry-run, benchmarks, and real loops.

``train_step`` is one optimizer step (forward + backward + AdamW).
``prefill_step`` runs the full-sequence forward, emitting last-token logits.
``serve_step`` decodes one token against an explicit KV/state cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import decode_step as model_decode_step
from ..models import forward, lm_loss
from ..models.config import ModelConfig
from ..models.scan_utils import _scan
from ..models.transformer import chunked_lm_loss
from ..models.tuning import get_tuning
from ..optim import AdamWConfig, adamw_init, adamw_update


def split_batch(batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    extras = {k: v for k, v in batch.items() if k not in ("tokens",)}
    return batch["tokens"], extras


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    remat: str = "full"):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        tun = get_tuning()
        tokens, extras = split_batch(batch)

        def loss_fn(p, tok, ext):
            out, _, aux = forward(cfg, p, tok[:, :-1], extras=ext, remat=remat)
            if tun.loss_chunk:
                return chunked_lm_loss(cfg, p, out, tok[:, 1:], aux, tun.loss_chunk)
            return lm_loss(cfg, out, tok[:, 1:], aux)

        mb = tun.microbatch
        if mb > 1 and tokens.shape[0] % mb == 0:
            # gradient accumulation: divides saved-activation memory by mb
            toks = tokens.reshape(mb, tokens.shape[0] // mb, *tokens.shape[1:])
            exts = {
                k: v.reshape(mb, v.shape[0] // mb, *v.shape[1:])
                for k, v in extras.items()
            }

            def body(acc, xs):
                tok_mb = xs[0]
                ext_mb = xs[1]
                loss_mb, g = jax.value_and_grad(loss_fn)(params, tok_mb, ext_mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc[0], g
                )
                return (acc_g, acc[1] + loss_mb), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = _scan(body, (zero, 0.0), (toks, exts))
            grads = jax.tree_util.tree_map(lambda g: (g / mb), gsum)
            loss = lsum / mb
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, extras)
        new_params, new_opt = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        tokens, extras = split_batch(batch)
        logits, _, _ = forward(cfg, params, tokens, extras=extras, last_only=True)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch, cache_index):
        tokens, extras = split_batch(batch)
        logits, new_cache = model_decode_step(
            cfg, params, cache, tokens, cache_index, extras=extras
        )
        return logits, new_cache

    return serve_step


def make_step(cfg: ModelConfig, kind: str, remat: str = "full"):
    if kind == "train":
        return make_train_step(cfg, remat=remat)
    if kind == "prefill":
        return make_prefill_step(cfg)
    if kind == "decode":
        return make_serve_step(cfg)
    raise ValueError(kind)
