import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import — jax locks the
# device count on first init, and the production meshes below need 512
# placeholder host devices. Only this module sets the flag; smoke tests and
# benchmarks see the single real CPU device.

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell: resolve the sharding
profile, build allocation-free abstract inputs (ShapeDtypeStruct), then
``jax.jit(step).lower(...).compile()`` and record memory/cost analysis plus
the collective schedule parsed from the optimized per-device HLO. Failures
(sharding mismatch, OOM-at-compile, unsupported collective) are bugs in the
system, not in the driver.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_wire_bytes
from repro.analysis.memory_est import estimate_hbm
from repro.analysis.roofline import model_flops, roofline_terms
from repro.models.scan_utils import scan_unroll
from repro.configs import ARCHITECTURES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step
from repro.models import (
    SHAPES,
    abstract_params,
    cache_descs,
    param_descs,
    shape_by_name,
)
from repro.models.params import is_desc, resolve_specs
from repro.parallel.sharding import (
    batch_dtypes,
    batch_input_descs,
    mesh_axis_sizes,
    profile_for,
    tree_shardings,
)


def scaled_pair(cfg):
    """Two pattern-preserving shallow variants for cost extrapolation.

    XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
    count (verified empirically), so scanned stacks under-report flops/bytes/
    collectives. The probes lower with fully UNROLLED stacks (scan_unroll
    context) on (small, large) configs differing by exactly one repeated
    unit of the stack pattern, then extrapolate linearly:
        cost(full) = cost(small) + extra_units * (cost(large) - cost(small))
    This is exact: the HLO of the repeated unit is identical at any depth.
    Returns (small_cfg, large_cfg, extra_units).
    """
    import dataclasses as dc

    if cfg.family == "encdec":
        assert cfg.encoder_layers == cfg.num_layers
        small = dc.replace(cfg, num_layers=2, encoder_layers=2)
        large = dc.replace(cfg, num_layers=4, encoder_layers=4)
        return small, large, (cfg.num_layers - 2) // 2
    if cfg.global_period:  # gemma3 pattern: groups of p + tail
        p = cfg.global_period
        tail = cfg.num_layers % p
        small = dc.replace(cfg, num_layers=p + tail)
        large = dc.replace(cfg, num_layers=2 * p + tail)
        return small, large, (cfg.num_layers - (p + tail)) // p
    if cfg.moe is not None and cfg.moe.first_k_dense:
        fk = cfg.moe.first_k_dense
        small = dc.replace(cfg, num_layers=fk + 2)
        large = dc.replace(cfg, num_layers=fk + 4)
        return small, large, (cfg.num_layers - fk - 2) // 2
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        tail = cfg.num_layers % p
        small = dc.replace(cfg, num_layers=p + tail)
        large = dc.replace(cfg, num_layers=2 * p + tail)
        return small, large, (cfg.num_layers - (p + tail)) // p
    if cfg.family == "vlm":
        p = cfg.cross_attn_period
        small = dc.replace(cfg, num_layers=p)
        large = dc.replace(cfg, num_layers=2 * p)
        return small, large, (cfg.num_layers - p) // p
    small = dc.replace(cfg, num_layers=2)
    large = dc.replace(cfg, num_layers=4)
    return small, large, (cfg.num_layers - 2) // 2


def extrapolate(small: dict, large: dict, extra: int) -> dict:
    """Linear two-point extrapolation, clamped at the small-probe value:
    GSPMD occasionally picks a cheaper collective strategy at depth (slope
    < 0), in which case the shallow probe is the conservative bound."""
    keys = set(small) | set(large)
    out = {}
    for k in keys:
        s = small.get(k, 0.0)
        l = large.get(k, 0.0)
        if not isinstance(s, (int, float)):
            continue
        v = s + extra * (l - s)
        out[k] = max(v, min(s, l), 0.0)
    return out


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return (
            "pure full-attention arch: 500k-token KV per layer is architecturally "
            "a non-goal (sub-quadratic archs run this cell; see DESIGN.md §4)"
        )
    return ""


def _abstract(descs, dtype):
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), descs, is_leaf=is_desc
    )


def _abstract_batch(bdescs, dtypes):
    return {
        k: jax.ShapeDtypeStruct(d.shape, dtypes.get(k, jnp.int32))
        for k, d in bdescs.items()
    }


def _compile_cell(cfg, shape, mesh, remat: str):
    """Lower + compile one (cfg, shape) on mesh; returns the Compiled."""
    profile = profile_for(cfg, shape, mesh)
    pdescs = param_descs(cfg)
    p_abs = abstract_params(pdescs, jnp.bfloat16)
    p_shard = tree_shardings(pdescs, profile, mesh)
    bdescs = batch_input_descs(cfg, shape)
    b_abs = _abstract_batch(bdescs, batch_dtypes(cfg))
    b_shard = tree_shardings(bdescs, profile, mesh)
    scalar_shard = NamedSharding(mesh, P())

    from repro.parallel.ep_moe import ep_mesh

    step = make_step(cfg, shape.kind, remat=remat)
    with mesh, ep_mesh(mesh):
        if shape.kind == "train":
            opt_abs = {
                "m": _abstract(pdescs, jnp.float32),
                "v": _abstract(pdescs, jnp.float32),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            opt_shard = {"m": p_shard, "v": p_shard, "step": scalar_shard}
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, scalar_shard),
            )
            lowered = jitted.lower(p_abs, opt_abs, b_abs)
        elif shape.kind == "prefill":
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_abs, b_abs)
        else:  # decode
            cdescs = cache_descs(cfg, batch=shape.global_batch, max_len=shape.seq_len)
            c_abs = _abstract(cdescs, jnp.bfloat16)
            c_shard = tree_shardings(cdescs, profile, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard, scalar_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),  # serve loops donate the cache: the
                # dynamic-update-slice becomes in-place, not a full copy
            )
            lowered = jitted.lower(
                p_abs, c_abs, b_abs, jax.ShapeDtypeStruct((), jnp.int32)
            )
        return lowered.compile(), profile


def _cost_and_collectives(compiled):
    cost = compiled.cost_analysis() or {}
    cost = {
        k: float(v)
        for k, v in cost.items()
        if k == "flops" or k.startswith("bytes accessed")
    }
    coll = collective_wire_bytes(compiled.as_text())
    return cost, coll


def build_cell(arch: str, shape_name: str, multi_pod: bool, remat: str = "full",
               variant: str = "baseline", tune: dict = None):
    """Lower + compile one cell; returns the result record.

    The FULL config is compiled (the deliverable: sharding coherence + memory
    analysis); flops/bytes/collectives are two-point extrapolated from
    pattern-preserving shallow variants because HloCostAnalysis counts scan
    bodies once (see scaled_pair). ``tune`` applies §Perf knobs
    (models/tuning.py) and tags the record with ``variant``."""
    from repro.models.tuning import tuning

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "variant": variant,
    }
    _tuning_ctx = tuning(**(tune or {}))
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec

    # 1) full-config compile: the coherence proof + raw XLA memory numbers
    t0 = time.time()
    with _tuning_ctx:
        compiled, profile = _compile_cell(cfg, shape, mesh, remat)
    rec.update(status="ok", compile_s=round(time.time() - t0, 2), profile=profile.name)
    try:
        mem = compiled.memory_analysis()
        # NOTE: the CPU backend has no buffer liveness: temp ~= bytes
        # accessed. Recorded raw; the fits-in-HBM proof is memory_est below.
        rec["memory_xla_raw"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes_no_liveness": int(getattr(mem, "temp_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        rec["memory_xla_raw"] = {"unavailable": str(e)}
    with tuning(**(tune or {})):
        rec["memory_est"] = {
            k: (round(v, 4) if isinstance(v, float) else v)
            for k, v in estimate_hbm(
                cfg, shape, profile.rules, mesh_axis_sizes(mesh), remat
            ).items()
        }

    # 2) cost terms: two-point extrapolation over UNROLLED shallow probes
    small, large, extra = scaled_pair(cfg)
    with tuning(**(tune or {})), scan_unroll():
        c_small, _ = _compile_cell(small, shape, mesh, remat)
        c_large, _ = _compile_cell(large, shape, mesh, remat)
    cost_s, coll_s = _cost_and_collectives(c_small)
    cost_l, coll_l = _cost_and_collectives(c_large)
    rec["cost"] = extrapolate(cost_s, cost_l, extra)
    rec["collectives"] = {
        k: round(v, 1) for k, v in extrapolate(coll_s, coll_l, extra).items()
    }
    rec["cost_method"] = f"two-point unrolled extrapolation (+{extra} units)"

    rec["roofline"] = {
        k: (round(v, 6) if isinstance(v, float) else v)
        for k, v in roofline_terms(
            rec["cost"], rec["collectives"], cfg, shape, chips
        ).items()
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[s.name for s in SHAPES] + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    # §Perf tuning knobs (models/tuning.py); tag runs with --variant
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--decode-seq-constraint", action="store_true")
    ap.add_argument("--constrain-activations", action="store_true")
    ap.add_argument("--moe-impl", default="einsum", choices=["einsum", "ep"])
    args = ap.parse_args()
    tune = dict(
        loss_chunk=args.loss_chunk,
        microbatch=args.microbatch,
        decode_seq_constraint=args.decode_seq_constraint,
        constrain_activations=args.constrain_activations,
        moe_impl=args.moe_impl,
    )

    archs = ARCHITECTURES if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = set()
    out_path = Path(args.out) if args.out else None
    if out_path and out_path.exists() and not args.force:
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                done.add((r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline")))
            except Exception:
                pass

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape_name in shapes:
                key = (arch, shape_name, mesh_name, args.variant)
                if key in done:
                    continue
                try:
                    rec = build_cell(
                        arch, shape_name, multi_pod,
                        remat=args.remat, variant=args.variant, tune=tune,
                    )
                except Exception:
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "variant": args.variant,
                        "status": "failed", "error": traceback.format_exc(limit=4),
                    }
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                line = json.dumps(rec)
                if out_path:
                    out_path.parent.mkdir(parents=True, exist_ok=True)
                    with open(out_path, "a") as f:
                        f.write(line + "\n")
                brief = {k: rec.get(k) for k in ("arch", "shape", "mesh", "status", "compile_s")}
                if st == "ok":
                    brief["dominant"] = rec["roofline"]["dominant"]
                    brief["roofline_fraction"] = rec["roofline"]["roofline_fraction"]
                    # proves it fits / cost source for §Roofline:
                    brief["hbm_frac"] = rec["memory_est"]["hbm_fraction"]
                    brief["fits_16g"] = rec["memory_est"]["fits_16g"]
                    brief["flops_per_chip"] = rec["cost"].get("flops")
                print(json.dumps(brief), flush=True)
                if st == "failed":
                    print(rec["error"], flush=True)
    print(f"dryrun: ok={n_ok} skipped={n_skip} failed={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
