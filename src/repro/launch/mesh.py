"""Production mesh construction (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only the dry-run is allowed to install the 512-placeholder-device flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod: 2 pods = 512
    chips with a pure-DP "pod" axis (cross-pod traffic = grad all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip).
TPU_V5E = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_link_bw": 50e9,         # B/s per link (~; see EXPERIMENTS.md)
    "hbm_bytes": 16 * 2**30,
}
