"""Training launcher.

Local mode (this container): runs the DSE-resilient training loop on a
reduced config with optional failure injection.

Cluster mode (TPU pods): the same entry point would initialize
jax.distributed and build the production mesh; per-host process launch is
scripts/launch_pod.sh. On CPU we validate the mesh path via the dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 20 \
      --kill-at 10 --out /tmp/run
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--kill-data-at", type=int, default=None)
    ap.add_argument("--group-commit-ms", type=float, default=20.0)
    ap.add_argument("--delta-codec", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the exact published dims (TPU-scale; default "
                    "is the reduced smoke config for CPU)")
    ap.add_argument("--out", default="/tmp/repro_train")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.train import run_resilient_training

    cfg = get_config(args.arch, smoke=not args.full_config)
    res = run_resilient_training(
        Path(args.out),
        cfg,
        steps=args.steps,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        kill_trainer_at=args.kill_at,
        kill_data_at=args.kill_data_at,
        group_commit_interval=args.group_commit_ms / 1e3,
        use_delta_codec=args.delta_codec,
    )
    print(json.dumps({
        "arch": cfg.name,
        "final_step": res.final_step,
        "params_digest": res.params_digest,
        "rollbacks": res.rollbacks,
        "checkpoint_bytes": res.checkpoint_bytes,
        "first_loss": res.external_metrics[0][1] if res.external_metrics else None,
        "last_loss": res.external_metrics[-1][1] if res.external_metrics else None,
    }, indent=2))


if __name__ == "__main__":
    main()
