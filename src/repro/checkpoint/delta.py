"""Delta-compressed checkpoint codec.

Persistence bandwidth is the resource the paper's Fig. 10 shows DSE saving;
for the training instantiation we additionally compress successive versions:

  * PARAMS: a full fp32 base every ``base_every`` versions, int8 deltas with
    per-block scales in between (Pallas delta_encode kernel). Parameters are
    magnitude-homogeneous enough for block quantization of their step deltas.
  * OPTIMIZER MOMENTS: stored raw — m as fp16, v as fp32. Adam's second
    moment spans ~8 orders of magnitude and sits next to first-moment blocks
    in any flat stream; block-quantizing its deltas rounds small v entries
    to zero and the next update explodes (m/(sqrt(0)+eps)). Measured before
    this split: post-restore loss 6.2 -> 13+. Lesson recorded in
    EXPERIMENTS.md §Perf (training substrate).

Restore replays base + deltas for params and loads moments directly.
"""
from __future__ import annotations

import io
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops

_BLOCK = 1024


def _flatten(tree) -> Tuple[np.ndarray, List, List]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    flat = (
        np.concatenate([a.ravel().astype(np.float32) for a in arrs])
        if arrs
        else np.zeros(0, np.float32)
    )
    shapes = [(a.shape, a.dtype.str) for a in arrs]
    return flat, shapes, treedef


def _unflatten(flat: np.ndarray, shapes, treedef):
    out, off = [], 0
    for shape, dt in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off : off + n].astype(np.dtype(dt)).reshape(shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def _pad_blocks(flat: np.ndarray) -> np.ndarray:
    n = len(flat)
    nb = max(1, (n + _BLOCK - 1) // _BLOCK)
    padded = np.zeros(nb * _BLOCK, np.float32)
    padded[:n] = flat
    return padded.reshape(nb, _BLOCK)


class DeltaCheckpointCodec:
    def __init__(self, base_every: int = 8, use_kernel: bool = True) -> None:
        self.base_every = base_every
        self.use_kernel = use_kernel

    def encode(self, version: int, state, prev_flat: Optional[np.ndarray]):
        """state = (params, opt_state). Returns (blob, new_params_flat).
        prev_flat None => full params base."""
        params, opt = state
        p_flat, _, _ = _flatten(params)
        o_leaves, _ = jax.tree_util.tree_flatten(opt)
        opt_arrays: Dict[str, np.ndarray] = {}
        for i, leaf in enumerate(o_leaves):
            a = np.asarray(leaf)
            if a.dtype == np.float32 and a.ndim >= 1 and "m" not in opt_arrays:
                pass  # dtype policy handled below per leaf index
            opt_arrays[f"o{i}"] = a
        # dtype policy: fp32 leaves of the FIRST moment tree -> fp16; the
        # rest (v, step) stay at full precision. The opt dict layout is
        # {"m": tree, "v": tree, "step": scalar}; flatten order is m*, step, v*
        # — we conservatively detect by magnitude instead: fp16 only when the
        # leaf round-trips within 1e-3 relative error.
        for k, a in list(opt_arrays.items()):
            if a.dtype == np.float32:
                a16 = a.astype(np.float16)
                denom = np.maximum(np.abs(a), 1e-12)
                if float(np.max(np.abs(a16.astype(np.float32) - a) / denom)) < 1e-3:
                    opt_arrays[k] = a16

        buf = io.BytesIO()
        is_base = prev_flat is None or len(prev_flat) != len(p_flat)
        if is_base:
            np.savez_compressed(buf, kind=np.array(0), flat=p_flat, **opt_arrays)
        else:
            new_b = _pad_blocks(p_flat)
            prev_b = _pad_blocks(prev_flat)
            if self.use_kernel:
                codes, scales = kops.delta_encode(
                    jnp.asarray(new_b), jnp.asarray(prev_b), interpret=True
                )
                codes, scales = np.asarray(codes), np.asarray(scales)
            else:
                from ..kernels import ref

                codes, scales = ref.delta_encode_ref(
                    jnp.asarray(new_b), jnp.asarray(prev_b)
                )
                codes, scales = np.asarray(codes), np.asarray(scales)
            np.savez_compressed(
                buf, kind=np.array(1), codes=codes, scales=scales,
                n=np.array(len(p_flat)), **opt_arrays,
            )
        return buf.getvalue(), p_flat

    def decode_chain(self, blobs: List[bytes], p_shapes, p_treedef,
                     o_shapes, o_treedef):
        """Replay [base, delta, ...]; the LAST blob carries the opt moments.
        Returns ((params, opt_state), params_flat)."""
        flat: Optional[np.ndarray] = None
        last = None
        for blob in blobs:
            z = np.load(io.BytesIO(blob))
            last = z
            if int(z["kind"]) == 0:
                flat = z["flat"]
            else:
                assert flat is not None, "delta before base"
                prev_b = _pad_blocks(flat)
                dec = kops.delta_decode(
                    jnp.asarray(z["codes"]), jnp.asarray(z["scales"]),
                    jnp.asarray(prev_b), dtype=jnp.float32, interpret=True,
                )
                flat = np.asarray(dec).ravel()[: int(z["n"])]
        assert flat is not None and last is not None
        params = _unflatten(flat, p_shapes, p_treedef)
        o_leaves = []
        for i, (shape, dt) in enumerate(o_shapes):
            a = np.asarray(last[f"o{i}"]).astype(np.dtype(dt)).reshape(shape)
            o_leaves.append(a)
        opt = jax.tree_util.tree_unflatten(o_treedef, o_leaves)
        return (params, opt), flat
