from .trainer_so import MetricsStateObject, TrainerStateObject
from .delta import DeltaCheckpointCodec

__all__ = ["MetricsStateObject", "TrainerStateObject", "DeltaCheckpointCodec"]
