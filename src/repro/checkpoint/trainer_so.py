"""Trainer / metrics StateObjects — the paper's StateObject abstraction
instantiated over JAX training state (DESIGN.md §2 mapping).

TrainerStateObject:
  * one ``train_on`` call = one libDSE action: it consumes the data
    pipeline's header (the batch-lineage edge) and emits a header for
    downstream consumers (metrics/eval/export);
  * ``Persist`` captures a consistent device snapshot (the runtime's
    exclusive epoch guarantees no step interleaves), then writes
    asynchronously — steps keep executing SPECULATIVELY past the
    checkpoint, which is exactly the paper's persistence-off-critical-path;
  * ``Restore`` loads params/opt/step; with the DeltaCheckpointCodec,
    versions between bases are int8 deltas (Pallas delta_encode kernel).

MetricsStateObject:
  * records (step, loss) under actions that consume trainer headers, so a
    rolled-back step's metric is rolled back with it;
  * ``flush_external`` is barrier-gated — the outside world only ever sees
    metrics that survive any failure (Failure Transparency).
"""
from __future__ import annotations

import io
import json
import threading
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.ids import Header
from ..core.state_object import StateObject, VersionStore
from .delta import DeltaCheckpointCodec, _flatten


class TrainerStateObject(StateObject):
    def __init__(
        self,
        root: Path,
        init_state_fn: Callable[[], Tuple],   # () -> (params, opt_state)
        step_fn: Callable,                    # (params, opt, batch) -> (params, opt, loss)
        codec: Optional[DeltaCheckpointCodec] = None,
    ) -> None:
        super().__init__()
        self.store = VersionStore(root, keep_in_memory=4)
        self.params, self.opt_state = init_state_fn()
        self._init_state_fn = init_state_fn
        self.step_fn = step_fn
        self.step = 0
        # loss history is part of trainer state: it rolls back and replays
        # atomically with params/step (exactly-once metrics reconciliation)
        self.loss_history: List[Tuple[int, float]] = []
        self.codec = codec
        self._prev_flat: Optional[np.ndarray] = None
        self._last_label: Optional[int] = None
        self._since_base = 0
        self._chain: Dict[int, bytes] = {}   # version -> blob (delta mode)
        self._shapes = None
        self._treedef = None
        self._mu = threading.Lock()
        self.bytes_written = 0

    # -- persistence ---------------------------------------------------------
    def _snapshot_blob(self, version: int) -> bytes:
        state = (self.params, self.opt_state)
        prev_label = None
        if self.codec is not None:
            # chain bookkeeping: a delta's parent is the LAST PERSISTED label
            # of this incarnation's lineage. Walking explicit parent pointers
            # at restore time is immune to stale blobs from rolled-back
            # incarnations that share label ranges (DESIGN.md §2 gaps).
            force_base = (
                self._prev_flat is None
                or self._since_base >= self.codec.base_every
            )
            body, self._prev_flat = self.codec.encode(
                version, state, None if force_base else self._prev_flat
            )
            prev_label = None if force_base else self._last_label
            self._since_base = 0 if force_base else self._since_base + 1
            self._last_label = version
            is_base = force_base
        else:
            buf = io.BytesIO()
            leaves, _ = jax.tree_util.tree_flatten(state)
            np.savez_compressed(buf, *[np.asarray(l) for l in leaves])
            body = buf.getvalue()
            is_base = True
        hdr = json.dumps({
            "step": self.step, "history": self.loss_history,
            "prev": prev_label, "base": is_base,
        }).encode()
        return len(hdr).to_bytes(4, "little") + hdr + body

    @staticmethod
    def _split_blob(blob: bytes):
        n = int.from_bytes(blob[:4], "little")
        hdr = json.loads(blob[4 : 4 + n].decode())
        return hdr, blob[4 + n :]

    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        # Snapshot must be consistent: runtime holds the exclusive epoch, so
        # no train action is in flight. device_get blocks on queued steps.
        blob = self._snapshot_blob(version)
        if self.codec is not None:
            self._chain[version] = blob

        def _io() -> None:
            try:
                self.store.write(version, blob, metadata)
            except RuntimeError:
                return
            self.bytes_written += len(blob)
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        hdr, body = self._split_blob(payload)
        if self.codec is not None:
            # walk explicit parent pointers down to a base (stale blobs from
            # rolled-back label ranges are never visited)
            bodies: List[bytes] = []
            v = version
            while True:
                blob = self._chain.get(v)
                if blob is None:
                    blob, _ = self.store.read(v)
                h, b = self._split_blob(blob)
                bodies.append(b)
                if h.get("base", True) or h.get("prev") is None:
                    break
                v = int(h["prev"])
            bodies.reverse()
            _, p_shapes, p_treedef = _flatten(self.params)
            _, o_shapes, o_treedef = _flatten(self.opt_state)
            state, flat = self.codec.decode_chain(
                bodies, p_shapes, p_treedef, o_shapes, o_treedef
            )
            self._prev_flat = flat
            self._last_label = version
            self._since_base = 0  # force a fresh base on the next persist
        else:
            z = np.load(io.BytesIO(body))
            leaves, treedef = jax.tree_util.tree_flatten(
                (self.params, self.opt_state)
            )
            state = jax.tree_util.tree_unflatten(treedef, [z[k] for k in z.files])
        self.params, self.opt_state = state
        self.step = int(hdr["step"])
        self.loss_history = [tuple(r) for r in hdr["history"]]
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        # keep delta-chain bases: prune only below the last base <= version
        if self.codec is not None:
            return  # simple policy: delta mode retains history (bounded runs)
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        self._chain = {}
        self._prev_flat = None
        self._last_label = None
        self._since_base = 0
        self.params, self.opt_state = self._init_state_fn()
        self.step = 0
        self.loss_history = []

    # -- service API -----------------------------------------------------------
    def train_on(self, step: int, tokens: np.ndarray, header: Optional[Header] = None,
                 extras: Optional[dict] = None):
        """One speculative train step. Returns (loss, header) or None."""
        if not self.StartAction(header):
            return None
        if step != self.step:
            # stale/duplicate batch relative to restored state: refuse inside
            # the action so the driver resyncs the cursor.
            self.EndAction()
            return ("resync", self.step)
        batch = {"tokens": tokens, **(extras or {})}
        self.params, self.opt_state, loss = self.step_fn(
            self.params, self.opt_state, batch
        )
        loss = float(loss)
        self.loss_history.append((self.step, loss))
        self.step += 1
        return loss, self.EndAction()

    def current_step(self) -> int:
        return self.step

    def history_snapshot(self):
        """(history, header) under an action — for metrics reconciliation
        after a rollback dropped records the trainer state still covers."""
        if not self.StartAction(None):
            return None
        out = list(self.loss_history)
        return out, self.EndAction()

    def params_digest(self) -> str:
        import hashlib

        flat, _, _ = _flatten(self.params)
        return hashlib.sha256(np.ascontiguousarray(flat)).hexdigest()[:16]


class MetricsStateObject(StateObject):
    def __init__(self, root: Path) -> None:
        super().__init__()
        self.store = VersionStore(root)
        self.records: List[Tuple[int, float]] = []
        self._mu = threading.Lock()

    def Persist(self, version: int, metadata: bytes, callback: Callable[[], None]) -> None:
        with self._mu:
            payload = json.dumps(self.records).encode()

        def _io() -> None:
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        self.spawn_io(_io)

    def Restore(self, version: int) -> bytes:
        payload, meta = self.store.read(version)
        with self._mu:
            self.records = [tuple(r) for r in json.loads(payload.decode())]
        return meta

    def ListVersions(self) -> List[Tuple[int, bytes]]:
        return self.store.list_versions()

    def Prune(self, version: int) -> None:
        self.store.prune(version)

    def on_crash(self) -> None:
        self.store.poison()
        self.store.drop_memory()
        with self._mu:
            self.records = []

    def record(self, step: int, loss: float, header: Optional[Header] = None) -> bool:
        if not self.StartAction(header):
            return False
        with self._mu:
            self.records.append((step, loss))
        self.EndAction()
        return True

    def flush_external(self, timeout: float = 30.0) -> List[Tuple[int, float]]:
        """Barrier-gated export: returns only non-speculative metrics."""
        if not self.StartAction(None):
            return []
        t = self.Detach()
        t.Barrier(timeout=timeout)
        if not self.Merge(t):
            return []
        with self._mu:
            out = list(self.records)
        self.EndAction()
        return out
