"""DurableRuntime — the synchronous durable-execution baseline (paper §2.1,
Figure 9 "current systems" bar), speaking the unmodified DSE protocol.

Semantics: nothing leaves a StateObject — no reply, no outgoing message, no
sthread — until (a) the state it derives from is durable on disk AND (b) the
coordinator has acknowledged the persist report. Every ``EndAction`` /
``Detach`` therefore pays a full synchronous persist + report round-trip,
which is exactly the per-step durability wait Temporal/Beldi/Boki-class
engines charge (and what DSE's speculation removes from the latency path).

Why (b) and not just local durability: the coordinator computes rollback
targets on its *reported* view (paper §4.3); a durable-but-unreported vertex
is above its owner's target and would be rolled back, i.e. an exposed result
could be lost — exactly what "durable execution" promises never happens.
Blocking exposure on the report ack closes that window, and makes the
invariant exact: every header this runtime ever emits references a vertex
inside the coordinator's view, so every rollback decision in an all-durable
cluster is a no-op on durable state (only in-flight action state is lost).
That is the property the differential oracle (``repro.sim.differential``)
leans on.

Implementation: a thin subclass of :class:`~repro.core.runtime.DSERuntime`
— header classification, decision application, recovery, barriers, and the
coordinator protocol are deliberately shared (the baseline must speak the
same wire protocol to run on the same clusters/fabrics); only the action
commit path changes. Select it with ``DSEConfig(runtime="durable")`` or
``LocalCluster/NetCluster/SimCluster(..., runtime="durable")``.
"""
from __future__ import annotations

from ..core.ids import Header, Vertex
from ..core.runtime import DSERuntime
from ..core.sthread import RolledBackError, SThread


class DurableRuntime(DSERuntime):
    kind = "durable"

    # ------------------------------------------------------------------ #
    # action lifecycle: commit synchronously before anything escapes     #
    # ------------------------------------------------------------------ #
    def end_action(self) -> Header:
        self._epoch.release_shared()
        return Header.of(self._commit_sync())

    def detach(self) -> SThread:
        self._epoch.release_shared()
        return SThread(self, {self._commit_sync()})

    def _commit_sync(self) -> Vertex:
        """Persist the current state, wait until it is durable AND its
        report is acknowledged by the coordinator, then return the (now
        non-speculative) vertex the caller may expose.

        Called with no locks held (the shared epoch is released first: the
        persist path takes the exclusive epoch, and holding shared across it
        would deadlock). A concurrent action committing between the release
        and the snapshot only means our effects ride its (also synchronous)
        persist — the label returned always covers our action's effects.
        """
        # ``world`` is the epoch the snapshot actually carries (taken under
        # the exclusive epoch inside _persist_begin, so no decision can
        # interleave): the admission mark, the invalidation check, and the
        # returned vertex below all key on the same (world, label) pair.
        label, done, world = self._persist_begin()
        # durability wait — poll-free except for liveness: a crashed
        # incarnation's store never acks, so re-check aliveness periodically
        # instead of blocking forever.
        while not done.wait(timeout=0.05):
            self._check_alive()
        # admission-ack wait: retry the flush across transport faults (the
        # coordinator-side (world, seq) dedup makes the at-least-once resend
        # single-count). ``report`` returns the vertices a decision already
        # invalidated, and only ADMITTED vertices advance _flushed_marks —
        # "delivered but dropped" must not count as durable (the dropped
        # vertex is above its rollback target and will be rolled back).
        while True:
            with self._mu:
                if self._flushed_marks.get(world, -1) >= label:
                    break  # durable AND inside the coordinator's view
                if self.world != world and self._dindex.invalidates(
                    Vertex(self.so_id, world, label)
                ):
                    # A rollback decision landed mid-commit and took our
                    # label with it. Durable execution fails the request
                    # rather than ack state that no longer exists; the
                    # caller's driver retries against the recovered state.
                    raise RolledBackError(
                        f"{self.so_id}: commit of v{label} interrupted by "
                        f"rollback to epoch {self.world}"
                    )
                pending = bool(self._report_queue)
            self._check_alive()
            if pending:
                try:
                    self._flush_reports()
                    continue
                except Exception:
                    self.clock.sleep(self.config.barrier_poll_interval)
                    continue  # fabric fault: back off, retry
            # Nothing left to flush, yet no admission mark: either a
            # concurrent flusher owns our report (its ack will land), or the
            # coordinator rejected it (a decision exists that we have not
            # applied yet) — poll so the decision/world catches up and the
            # invalidation check above can resolve the wait.
            try:
                self._poll_coordinator()
            except Exception:
                pass  # transient fabric fault: poll again next beat
            self.clock.sleep(self.config.barrier_poll_interval)
        with self._mu:
            vertex = Vertex(self.so_id, world, label)
        # Eager fragment GC (DESIGN.md §11): the durable baseline persists
        # one version per action, so leaving pruning to the background
        # Refresh lets the store (and every reconnect/resend) grow by the
        # full action rate between boundary ships. The floor was durably
        # exposed before this commit returned, so pruning here is sound.
        self._apply_prune()
        return vertex
