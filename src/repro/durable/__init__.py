"""repro.durable — synchronous durable-execution baseline runtime.

The paper's Figure-9 baseline (Temporal / Durable-Functions / Beldi-style
per-step synchronous persistence) generalized from workflows to every
StateObject service, and the repo's differential-test oracle: a runtime
that persists synchronously before every externally-visible effect is
trivially correct, so any divergence from the speculative stack under
identical ops and faults is a bug in speculation/rollback
(``repro.sim.differential``).
"""
from .runtime import DurableRuntime

__all__ = ["DurableRuntime"]
