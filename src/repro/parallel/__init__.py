from .sharding import (
    ShardingProfile,
    batch_input_descs,
    make_rules,
    profile_for,
    tree_shardings,
)

__all__ = [
    "ShardingProfile",
    "batch_input_descs",
    "make_rules",
    "profile_for",
    "tree_shardings",
]
