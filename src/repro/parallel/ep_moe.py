"""Expert-parallel MoE dispatch via shard_map + all_to_all (beyond-paper).

The GShard grouped-einsum dispatch (layers.moe) is GSPMD-native but costs
O(T·E·C·D) einsum flops — measured 40-50x the experts themselves for
granite's tiny d_expert=512 (useful_ratio 0.02, EXPERIMENTS §Roofline).
This module is the DeepSeek-style alternative: tokens are routed LOCALLY
per data shard (scatter into per-expert capacity buckets — O(T·D), no
one-hot einsums), exchanged with the expert owners via all_to_all over the
"model" axis, transformed, and returned. Dispatch cost collapses to
gather/scatter + 2 all_to_alls of (E, C_loc, D).

Enabled per-cell with tuning(moe_impl="ep"); numerically equivalent to the
einsum path when nothing overflows capacity (tests/test_ep_moe.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

F32 = jnp.float32

# ambient mesh for shard_map (set by the dry-run / launcher around lowering)
_EP_MESH = None


class ep_mesh:
    def __init__(self, mesh) -> None:
        self.mesh = mesh

    def __enter__(self):
        global _EP_MESH
        self._prev = _EP_MESH
        _EP_MESH = self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _EP_MESH
        _EP_MESH = self._prev


def get_ep_mesh():
    return _EP_MESH


def _local_moe(xf, router, w_gate, w_up, w_down, *, cfg: ModelConfig,
               model_axis: str, e_pad: int) -> Tuple[jax.Array, jax.Array]:
    """Per-device block code. xf: (T_loc, D); expert weights: (E_pad/M, D, F).
    e_pad >= num_experts is the padded expert count (multiple of M); padded
    experts receive no tokens (router never selects them)."""
    mo = cfg.moe
    T, D = xf.shape
    E, k = mo.num_experts, mo.top_k
    M = jax.lax.psum(1, model_axis)

    logits = (xf @ router).astype(F32)                      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, ids = jax.lax.top_k(probs, k)                   # (T, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids, E, dtype=F32).sum(1), axis=0) / k
    aux = E * jnp.sum(me * ce) * mo.router_aux_weight

    cap = int(np.ceil(T * k / E * mo.capacity_factor))
    # slot within the chosen expert, (t, k)-priority — O(T·E) ints, no einsum
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32).reshape(T * k, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot).reshape(T, k, E)
    pos_sel = jnp.take_along_axis(pos, ids[..., None], axis=-1)[..., 0]  # (T,k)
    keep = pos_sel < cap
    slot = jnp.where(keep, ids * cap + pos_sel, e_pad * cap)  # e_pad*cap = drop

    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    buf = jnp.zeros((e_pad * cap, D), xf.dtype)
    buf = buf.at[slot.ravel()].add(xf[tok_idx.ravel()], mode="drop")
    buf = buf.reshape(e_pad, cap, D)

    # ship each expert's bucket to its owner shard; receive M buckets for
    # each local expert: (E, C, D) -> (E/M, M*C, D)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                             tiled=True)

    act = jax.nn.gelu if cfg.activation == "gelu" else jax.nn.silu
    h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    out = jnp.einsum("ecf,efd->ecd", h, w_down)             # (E/M, M*C, D)

    out = jax.lax.all_to_all(out, model_axis, split_axis=1, concat_axis=0,
                             tiled=True)                    # (E_pad, C, D)
    out = out.reshape(e_pad * cap, D)
    y_tk = jnp.take(out, jnp.where(keep, slot, 0), axis=0)  # (T, k, D)
    y_tk = y_tk * (keep[..., None] * gate_w[..., None]).astype(xf.dtype)
    return y_tk.sum(axis=1), aux


def ep_moe(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig):
    """Drop-in for layers.moe's routed part. Requires an ep_mesh context.

    Tokens shard over (batch_axes, "model"): each model shard routes its
    OWN sequence slice (otherwise every shard would build and process an
    identical full dispatch buffer — M-fold duplicated expert work,
    observed as a 2x compute regression on deepseek before this layout).
    Experts pad up to a multiple of |model| (granite: 40 -> 48); padded
    experts are never routed to."""
    from jax.experimental.shard_map import shard_map

    mesh = get_ep_mesh()
    assert mesh is not None, "ep_moe requires an ep_mesh(...) context"
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    m_size = mesh.shape["model"]
    B, S, D = x.shape
    E = cfg.moe.num_experts
    e_pad = ((E + m_size - 1) // m_size) * m_size
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if e_pad != E:
        padn = e_pad - E
        wg = jnp.pad(wg, ((0, padn), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, padn), (0, 0), (0, 0)))
        wd = jnp.pad(wd, ((0, padn), (0, 0), (0, 0)))
    seq_shardable = S % m_size == 0
    x_spec = (
        P(batch_axes, "model", None) if seq_shardable else P(batch_axes, None, None)
    )

    def body(xb, router, wg, wu, wd):
        T = xb.shape[0] * xb.shape[1]
        y, aux = _local_moe(
            xb.reshape(T, D), router, wg, wu, wd,
            cfg=cfg, model_axis="model", e_pad=e_pad,
        )
        # aux is per-shard; average across the whole mesh
        aux = jax.lax.pmean(aux, batch_axes + ("model",))
        return y.reshape(xb.shape), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,                      # x: batch (and seq) sharded
            P(None, None),               # router: replicated
            P("model", None, None),      # experts: sharded over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, p["router"], wg, wu, wd)
    return y, aux
