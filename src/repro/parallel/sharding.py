"""Sharding profiles: logical-axis -> mesh-axis rules per (arch x shape).

Parallelism features at 1000+-node scale (DESIGN.md §6):
  * DP: batch over ("pod", "data") — pods are a pure-DP outer axis, so the
    only cross-pod (DCI) traffic is the gradient all-reduce;
  * TP: heads / kv_heads / ffn / vocab / experts over "model";
  * FSDP (2D): for params too large to replicate per data shard (llama-90B),
    the "embed" dim of every weight additionally shards over "data"
    (params+optimizer divide by 16*16=256);
  * SP-ish decode fallback: when kv_heads cannot divide "model" (MQA), the
    KV-cache *sequence* dim shards over "model" (flash-decode style: GSPMD
    inserts the partial-softmax combine);
  * EP: MoE experts over "model" when divisible, else expert_ffn.

Divisibility is checked per-leaf by ``resolve_spec``; anything that does not
divide falls back one level and ultimately to replication — the dry-run
records the outcome rather than crashing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.config import ModelConfig, ShapeConfig
from ..models.params import PDesc, resolve_specs


@dataclass(frozen=True)
class ShardingProfile:
    name: str
    rules: Dict[str, Tuple[str, ...]]


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_rules(mesh: Mesh, *, kind: str, fsdp: bool = False) -> ShardingProfile:
    batch = _batch_axes(mesh)
    rules: Dict[str, Tuple[str, ...]] = {
        "batch": batch,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "expert_ffn": ("model",),   # fallback when experts % model != 0
    }
    if fsdp:
        rules["embed"] = ("data",)  # 2D: TP x FSDP
    if kind in ("decode", "prefill"):
        rules["seq"] = ("model",)   # fallback when kv_heads can't shard (MQA)
    name = f"{kind}{'_fsdp' if fsdp else ''}"
    return ShardingProfile(name, rules)


#: archs whose params+optimizer do not fit replicated-per-data-shard.
_FSDP_REQUIRED = {"llama-3.2-vision-90b"}
#: archs large enough that FSDP is the sensible default even if not forced.
_FSDP_PREFERRED = {"glm4-9b", "deepseek-v2-lite-16b", "yi-6b"}


def profile_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> ShardingProfile:
    fsdp = shape.kind == "train" and (
        cfg.name in _FSDP_REQUIRED or cfg.name in _FSDP_PREFERRED
    )
    return make_rules(mesh, kind=shape.kind, fsdp=fsdp)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def tree_shardings(descs, profile: ShardingProfile, mesh: Mesh):
    """PDesc tree -> NamedSharding tree."""
    specs = resolve_specs(descs, profile.rules, mesh_axis_sizes(mesh))
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


# --------------------------------------------------------------------------- #
# model inputs as descriptor trees (shared by dry-run and real runs)           #
# --------------------------------------------------------------------------- #
def batch_input_descs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, PDesc]:
    """Descriptor tree for one step's inputs (tokens + stub modality)."""
    B = shape.global_batch
    if shape.kind == "train":
        descs = {"tokens": PDesc((B, shape.seq_len + 1), ("batch", "seq"))}
    elif shape.kind == "prefill":
        descs = {"tokens": PDesc((B, shape.seq_len), ("batch", "seq"))}
    else:  # decode: one new token against a seq_len-deep cache
        descs = {"tokens": PDesc((B, 1), ("batch", None))}
    if cfg.family == "encdec":
        descs["frames"] = PDesc((B, cfg.source_len, cfg.d_model), ("batch", None, None))
    if cfg.family == "vlm":
        descs["image_embeds"] = PDesc(
            (B, cfg.num_image_tokens, cfg.d_model), ("batch", None, None)
        )
    return descs


def batch_dtypes(cfg: ModelConfig) -> Dict[str, object]:
    out = {"tokens": jnp.int32}
    if cfg.family == "encdec":
        out["frames"] = jnp.bfloat16
    if cfg.family == "vlm":
        out["image_embeds"] = jnp.bfloat16
    return out
