"""Analytic per-chip HBM estimate for the dry-run.

The CPU backend's ``memory_analysis()`` lacks buffer liveness (temp bytes
approximately equal total bytes accessed), so the fits-in-HBM proof uses an
analytic model over the *sharded* descriptor trees — exact for params /
optimizer / caches (they are declared trees with resolved PartitionSpecs),
estimated for activations:

  train  : params + grads + 2x fp32 moments + L x (saved layer input) [remat]
           + fp32 logits(+grad) working set
  prefill: params + ~4 live layer intermediates + last-token logits
  decode : params + KV/state cache + O(B*D) working set
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..launch.mesh import TPU_V5E
from ..models.config import ModelConfig, ShapeConfig
from ..models.params import PDesc, is_desc, resolve_specs
import jax


def _shard_factor(spec, sizes: Dict[str, int]) -> int:
    f = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            f *= sizes.get(a, 1)
    return f


def sharded_tree_bytes(descs, rules, sizes, elt_bytes: int) -> int:
    from jax.sharding import PartitionSpec

    specs = resolve_specs(descs, rules, sizes)
    d_leaves = jax.tree_util.tree_leaves(descs, is_leaf=is_desc)
    s_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec)
    )
    assert len(d_leaves) == len(s_leaves)
    total = 0
    for d, s in zip(d_leaves, s_leaves):
        n = int(np.prod(d.shape)) if d.shape else 1
        total += n * elt_bytes // _shard_factor(s, sizes)
    return total


def estimate_hbm(cfg: ModelConfig, shape: ShapeConfig, rules, sizes, remat: str) -> Dict:
    from ..models import cache_descs, param_descs

    batch_axes = [a for a in ("pod", "data") if a in sizes]
    b_shards = int(np.prod([sizes[a] for a in batch_axes])) or 1
    m = sizes.get("model", 1)
    b_loc = max(shape.global_batch // b_shards, 1)
    d = cfg.d_model
    v_loc = cfg.vocab_padded // m if cfg.vocab_padded % m == 0 else cfg.vocab_padded

    pdescs = param_descs(cfg)
    params_b = sharded_tree_bytes(pdescs, rules, sizes, 2)
    out: Dict[str, float] = {"params": params_b}

    if shape.kind == "train":
        from ..models.tuning import get_tuning

        tun = get_tuning()
        out["optimizer_fp32"] = sharded_tree_bytes(pdescs, rules, sizes, 4) * 2
        out["grads"] = params_b
        saved_per_layer = b_loc * shape.seq_len * d * 2  # bf16 layer input
        n_saved = cfg.num_layers + cfg.encoder_layers
        mult = {"full": 1.0, "dots": 4.0, "none": 10.0}[remat]
        out["activations_saved"] = saved_per_layer * n_saved * mult / tun.microbatch
        s_eff = min(shape.seq_len, tun.loss_chunk) if tun.loss_chunk else shape.seq_len
        out["logits_ws_fp32"] = 2 * (b_loc // tun.microbatch) * s_eff * v_loc * 4
        out["layer_working_set"] = 4 * saved_per_layer / tun.microbatch
    elif shape.kind == "prefill":
        live = b_loc * shape.seq_len * d * 2
        out["layer_working_set"] = 6 * live
        out["logits"] = b_loc * v_loc * 4
    else:  # decode
        cdescs = cache_descs(cfg, batch=shape.global_batch, max_len=shape.seq_len)
        out["kv_cache"] = sharded_tree_bytes(cdescs, rules, sizes, 2) * 2  # in+out
        out["layer_working_set"] = 8 * b_loc * d * 2
        out["logits"] = b_loc * v_loc * 4

    out["total"] = float(sum(v for k, v in out.items()))
    out["hbm_fraction"] = out["total"] / TPU_V5E["hbm_bytes"]
    out["fits_16g"] = bool(out["total"] <= TPU_V5E["hbm_bytes"])
    return out
