from .hlo import collective_wire_bytes, parse_collectives
from .roofline import model_flops, roofline_terms

__all__ = ["collective_wire_bytes", "parse_collectives", "model_flops", "roofline_terms"]
