"""Turn dry-run JSONL results into the EXPERIMENTS.md roofline tables.

Usage: PYTHONPATH=src python -m repro.analysis.report results/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List


def load(path: Path) -> List[Dict]:
    rows = []
    seen = {}
    for line in Path(path).read_text().splitlines():
        try:
            r = json.loads(line)
        except Exception:
            continue
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(rows: List[Dict]) -> str:
    out = [
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "useful ratio | roofline frac | HBM est | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED | | | | | | | |"
            )
            continue
        rf = r["roofline"]
        me = r.get("memory_est", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['useful_ratio']:.3f} | {rf['roofline_fraction']:.4f} | "
            f"{me.get('hbm_fraction', float('nan')):.2f} | "
            f"{'yes' if me.get('fits_16g') else 'NO'} |"
        )
    return "\n".join(out)


def pick_hillclimb(rows: List[Dict]) -> Dict[str, Dict]:
    ok = [r for r in rows if r["status"] == "ok" and r["shape"] != "long_500k"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
    # most representative of the paper's technique: the training shape whose
    # persistence/step overlap matters most = largest model train cell
    train = [r for r in ok if r["shape"] == "train_4k"]
    rep = max(train, key=lambda r: r["roofline"]["model_flops_global"])
    return {"worst_fraction": worst, "most_collective_bound": coll, "representative": rep}


def main() -> None:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else Path("results/dryrun_single.jsonl")
    rows = load(path)
    print(f"## Roofline table ({path.name}, {len(rows)} cells)\n")
    print(roofline_table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        print("\n### Hillclimb candidates\n")
        for tag, r in pick_hillclimb(rows).items():
            print(
                f"- **{tag}**: {r['arch']} x {r['shape']} "
                f"(dominant={r['roofline']['dominant']}, "
                f"fraction={r['roofline']['roofline_fraction']:.4f})"
            )
    n_fail = sum(1 for r in rows if r["status"] == "failed")
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    print(f"\ncells: {len(rows)} ok={len(ok)} skipped={n_skip} failed={n_fail}")


if __name__ == "__main__":
    main()
