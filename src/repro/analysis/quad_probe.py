"""Measure the attention-quadratic share of the memory roofline term.

Method: HLO bytes at fixed token count T decompose as
    bytes(S, B) = linear(T) + quad * S        (attention S^2 per sequence =
                                               S * T total)
so compiling probes at (S, B) and (S/2, 2B) — same tokens, same parameter
traffic — isolates the quadratic part:
    quad_total = 2 * (bytes(S, B) - bytes(S/2, 2B))

The flash-attention Pallas kernel (kernels/flash_attention.py, validated
against ref.py) keeps all S^2 intermediates in VMEM tiles; per (batch,head)
the K/V working set at these shapes (<= 16 MB) fits VMEM, so its HBM
traffic is linear and the adjusted memory term is (total - quad). This is
the cost model for the TPU build, where attn_impl="pallas" replaces the XLA
S^2 path; the CPU dry-run cannot compile Mosaic kernels (interpret-only),
hence the measured-decomposition approach.

Usage:
  PYTHONPATH=src python -m repro.analysis.quad_probe --arch gemma_2b --shape train_4k
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses as dc
import json

from repro.analysis.roofline import roofline_terms
from repro.configs import get_config
from repro.launch.dryrun import _compile_cell, _cost_and_collectives, extrapolate, scaled_pair
from repro.launch.mesh import TPU_V5E, make_production_mesh
from repro.models import shape_by_name
from repro.models.scan_utils import scan_unroll


def probe_cost(cfg, shape, mesh, remat="full"):
    small, large, extra = scaled_pair(cfg)
    with scan_unroll():
        cs, _ = _compile_cell(small, shape, mesh, remat)
        cl, _ = _compile_cell(large, shape, mesh, remat)
    cost_s, coll_s = _cost_and_collectives(cs)
    cost_l, coll_l = _cost_and_collectives(cl)
    return extrapolate(cost_s, cost_l, extra), extrapolate(coll_s, coll_l, extra)


def quad_decompose(arch: str, shape_name: str, remat: str = "full"):
    mesh = make_production_mesh()
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    half = dc.replace(shape, seq_len=shape.seq_len // 2,
                      global_batch=shape.global_batch * 2)
    cost_full, coll_full = probe_cost(cfg, shape, mesh, remat)
    cost_half, _ = probe_cost(cfg, half, mesh, remat)

    b_full = cost_full["bytes accessed"]
    b_half = cost_half["bytes accessed"]
    quad = max(0.0, 2.0 * (b_full - b_half))
    f_full = cost_full["flops"]
    f_half = cost_half["flops"]
    quad_flops = max(0.0, 2.0 * (f_full - f_half))

    adj_cost = dict(cost_full)
    adj_cost["bytes accessed"] = b_full - quad
    base = roofline_terms(cost_full, coll_full, cfg, shape, mesh.devices.size)
    adj = roofline_terms(adj_cost, coll_full, cfg, shape, mesh.devices.size)
    return {
        "arch": arch, "shape": shape_name,
        "bytes_per_chip": b_full,
        "quad_bytes_per_chip": quad,
        "quad_fraction": quad / b_full if b_full else 0.0,
        "quad_flops_fraction": quad_flops / f_full if f_full else 0.0,
        "memory_s_xla": base["memory_s"],
        "memory_s_flash_adjusted": adj["memory_s"],
        "roofline_fraction_xla": base["roofline_fraction"],
        "roofline_fraction_flash_adjusted": adj["roofline_fraction"],
        "dominant_after": adj["dominant"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--constrain-activations", action="store_true")
    args = ap.parse_args()
    from repro.models.tuning import tuning

    with tuning(
        loss_chunk=args.loss_chunk,
        microbatch=args.microbatch,
        constrain_activations=args.constrain_activations,
    ):
        out = quad_decompose(args.arch, args.shape, args.remat)
    print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=2))


if __name__ == "__main__":
    main()
