"""Collective extraction from post-SPMD optimized HLO text.

``cost_analysis`` has no collective line item, so we parse
``compiled.as_text()`` (the per-device program after the SPMD partitioner)
and sum per-chip *wire* bytes for every collective op, using ring-algorithm
volume factors:

  all-gather(result R, groups of n):      R * (n-1)/n          sent per chip
  reduce-scatter(result R, groups of n):  R * (n-1)            (input = R*n)
  all-reduce(result R, groups of n):      2 * R * (n-1)/n      (RS + AG)
  all-to-all(result R, groups of n):      R * (n-1)/n
  collective-permute(result R):           R
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)  # e.g. replica_groups=[32,16]<=[512]...
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown: conservative minimum


def parse_collectives(hlo_text: str) -> List[Tuple[str, int, int]]:
    """Returns [(op_kind, result_bytes, group_size)] for each collective.
    '-done' ops are skipped (the '-start' carries the shape)."""
    out = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out.append((kind, _shape_bytes(shape_str), _group_size(line)))
    return out


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes, total and per op kind."""
    per_kind: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for kind, rbytes, n in parse_collectives(hlo_text):
        if n <= 1:
            continue
        if kind == "all-gather":
            b = rbytes * (n - 1) / n
        elif kind == "reduce-scatter":
            b = rbytes * (n - 1)
        elif kind == "all-reduce":
            b = 2 * rbytes * (n - 1) / n
        elif kind == "all-to-all":
            b = rbytes * (n - 1) / n
        else:  # collective-permute
            b = float(rbytes)
        per_kind[kind] += b
        counts[kind] += 1
    total = sum(per_kind.values())
    out = {"total": total}
    for k, v in per_kind.items():
        out[k] = v
        out[f"n_{k}"] = counts[k]
    return out
