"""Roofline-term derivation from the compiled dry-run artifact.

Three terms, all in seconds per step (per chip — the SPMD-partitioned HLO
module IS the per-chip program, so cost_analysis numbers are per chip):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes_accessed / HBM_bw
    collective = wire_bytes(parsed from HLO) / ICI_link_bw

plus MODEL_FLOPS (the analytically useful work: 6*N*D train, 2*N*D
inference, N_active for MoE) and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs which exposes remat/dispatch/redundancy waste.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..launch.mesh import TPU_V5E
from ..models.config import ModelConfig, ShapeConfig


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of routed experts + shared).
    Embedding lookups are excluded (standard 6ND convention counts only
    matmul params; the LM head IS included)."""
    total = cfg.param_count()
    total -= cfg.vocab_padded * cfg.d_model  # embedding gather is not a matmul
    if cfg.moe is not None:
        mo = cfg.moe
        n_moe_layers = cfg.num_layers - mo.first_k_dense
        per_expert = 3 * cfg.d_model * mo.d_expert
        inactive = (mo.num_experts - mo.top_k) * per_expert * n_moe_layers
        total -= inactive
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def roofline_terms(
    cost: Dict[str, float],
    collectives: Dict[str, float],
    cfg: ModelConfig,
    shape: ShapeConfig,
    chips: int,
    hw: Optional[Dict[str, float]] = None,
) -> Dict[str, float]:
    hw = hw or TPU_V5E
    flops_per_chip = float(cost.get("flops", 0.0))
    bytes_per_chip = float(cost.get("bytes accessed", 0.0))
    wire_per_chip = float(collectives.get("total", 0.0))

    compute_s = flops_per_chip / hw["peak_flops_bf16"]
    memory_s = bytes_per_chip / hw["hbm_bw"]
    collective_s = wire_per_chip / hw["ici_link_bw"]

    mf = model_flops(cfg, shape)
    mf_per_chip = mf / chips
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    bound = max(compute_s, memory_s, collective_s)
    ideal_s = mf_per_chip / hw["peak_flops_bf16"]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "hlo_flops_per_chip": flops_per_chip,
        "hlo_bytes_per_chip": bytes_per_chip,
        "wire_bytes_per_chip": wire_per_chip,
        "model_flops_global": mf,
        "model_flops_per_chip": mf_per_chip,
        "useful_ratio": (mf_per_chip / flops_per_chip) if flops_per_chip else 0.0,
        # fraction of the compute roofline achievable if the step runs at the
        # bound given by its dominant term (the score we hillclimb):
        "roofline_fraction": (ideal_s / bound) if bound > 0 else 0.0,
    }
