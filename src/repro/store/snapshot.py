"""Coordinator snapshot + manifest binary formats (DESIGN.md §11).

A snapshot captures one coordinator (or coordinator shard)'s **durable
cut**: everything a restarted coordinator needs so that recovery is
``load snapshot + replay log suffix`` instead of replaying the whole
history — the world counter, membership, the non-retired decision suffix,
the dependency-graph view at the exposure floor (per-StateObject committed
snapshots: live labels + dep lists), the floor itself, and the per-SO
report-flush dedup seqs.

Both blobs follow the ``core/ids.py`` wire conventions exactly: magic byte
``0xD5``, a kind byte (``K_SNAPSHOT`` / ``K_MANIFEST``, reserved there), a
per-blob so_id string table, zigzag varints, and strict truncated-buffer
rejection — a short read raises ``ValueError``, it never silently yields a
shortened durable cut (a torn snapshot must fail recovery loudly so the
manifest's previous generation is used instead; see ``compact.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple

from ..core.ids import (
    K_MANIFEST,
    K_SNAPSHOT,
    WIRE_MAGIC,
    RollbackDecision,
    _begin,
    _expect,
    _finish,
    _r_svarint,
    _r_uvarint,
    _read_decision_body,
    _str_at,
    _w_svarint,
    _w_uvarint,
    _write_decision_body,
)

#: bumped on any layout change; per the DESIGN.md §9 versioning rule a new
#: layout takes a new kind byte OR a new version value here — readers must
#: reject versions they do not understand (recovery then falls back to the
#: previous generation, never mis-parses).
SNAPSHOT_VERSION = 1

#: graph entry: sorted live labels with their dependency lists
GraphState = Dict[str, List[Tuple[int, List[Tuple[str, int]]]]]


@dataclass
class CoordinatorSnapshot:
    """In-memory form of one durable cut (see module docstring)."""

    fsn: int = 0
    retired_upto: int = 0  # decisions with fsn <= this were compacted away
    members: List[str] = field(default_factory=list)
    decisions: List[RollbackDecision] = field(default_factory=list)
    graph: GraphState = field(default_factory=dict)
    floor: Dict[str, int] = field(default_factory=dict)
    #: so_id -> set of (world, seq) report flushes already processed
    report_seen: Dict[str, Set[Tuple[int, int]]] = field(default_factory=dict)


def encode_snapshot(s: CoordinatorSnapshot) -> bytes:
    prefix, body, tab = _begin(K_SNAPSHOT)
    _w_uvarint(body, SNAPSHOT_VERSION)
    _w_uvarint(body, s.fsn)
    _w_uvarint(body, s.retired_upto)
    _w_uvarint(body, len(s.members))
    for so in sorted(s.members):
        _w_uvarint(body, tab.index(so))
    _w_uvarint(body, len(s.decisions))
    for d in sorted(s.decisions, key=lambda d: d.fsn):
        _write_decision_body(body, tab, d)
    _w_uvarint(body, len(s.graph))
    for so in sorted(s.graph):
        entries = s.graph[so]
        _w_uvarint(body, tab.index(so))
        _w_uvarint(body, len(entries))
        for version, deps in sorted(entries):
            _w_svarint(body, version)
            _w_uvarint(body, len(deps))
            for dep_so, dep_version in deps:
                _w_uvarint(body, tab.index(dep_so))
                _w_svarint(body, dep_version)
    _w_uvarint(body, len(s.floor))
    for so in sorted(s.floor):
        _w_uvarint(body, tab.index(so))
        _w_svarint(body, s.floor[so])
    _w_uvarint(body, len(s.report_seen))
    for so in sorted(s.report_seen):
        pairs = sorted(s.report_seen[so])
        _w_uvarint(body, tab.index(so))
        _w_uvarint(body, len(pairs))
        for world, seq in pairs:
            _w_svarint(body, world)
            _w_svarint(body, seq)
    return _finish(prefix, body, tab)


def decode_snapshot(raw: bytes) -> CoordinatorSnapshot:
    strings, i = _expect(raw, K_SNAPSHOT)
    version, i = _r_uvarint(raw, i)
    if version != SNAPSHOT_VERSION:
        raise ValueError(f"unsupported snapshot version {version}")
    s = CoordinatorSnapshot()
    s.fsn, i = _r_uvarint(raw, i)
    s.retired_upto, i = _r_uvarint(raw, i)
    n, i = _r_uvarint(raw, i)
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        s.members.append(_str_at(strings, si))
    n, i = _r_uvarint(raw, i)
    for _ in range(n):
        d, i = _read_decision_body(raw, i, strings)
        s.decisions.append(d)
    n, i = _r_uvarint(raw, i)
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        ne, i = _r_uvarint(raw, i)
        entries: List[Tuple[int, List[Tuple[str, int]]]] = []
        for _ in range(ne):
            version, i = _r_svarint(raw, i)
            nd, i = _r_uvarint(raw, i)
            deps: List[Tuple[str, int]] = []
            for _ in range(nd):
                di, i = _r_uvarint(raw, i)
                dv, i = _r_svarint(raw, i)
                deps.append((_str_at(strings, di), dv))
            entries.append((version, deps))
        s.graph[_str_at(strings, si)] = entries
    n, i = _r_uvarint(raw, i)
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        w, i = _r_svarint(raw, i)
        s.floor[_str_at(strings, si)] = w
    n, i = _r_uvarint(raw, i)
    for _ in range(n):
        si, i = _r_uvarint(raw, i)
        np, i = _r_uvarint(raw, i)
        pairs: Set[Tuple[int, int]] = set()
        for _ in range(np):
            world, i = _r_svarint(raw, i)
            seq, i = _r_svarint(raw, i)
            pairs.add((world, seq))
        s.report_seen[_str_at(strings, si)] = pairs
    if i != len(raw):
        raise ValueError(f"malformed snapshot: {len(raw) - i} trailing bytes")
    return s


# --------------------------------------------------------------------------- #
# manifest: the one-word commit record of the compactor                        #
# --------------------------------------------------------------------------- #
def encode_manifest(generation: int) -> bytes:
    out = bytearray((WIRE_MAGIC, K_MANIFEST))
    _w_uvarint(out, generation)
    return bytes(out)


def decode_manifest(raw: bytes) -> int:
    if len(raw) < 2 or raw[0] != WIRE_MAGIC or raw[1] != K_MANIFEST:
        raise ValueError(f"not a manifest blob (starts {raw[:2]!r})")
    gen, i = _r_uvarint(raw, 2)
    if i != len(raw):
        raise ValueError(f"malformed manifest: {len(raw) - i} trailing bytes")
    return gen
