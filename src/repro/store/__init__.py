"""repro.store — snapshot + log-compaction subsystem (DESIGN.md §11).

Bounded, O(live-state) coordinator recovery: a :class:`CompactingLog`
periodically folds the coordinator's durable state into a binary
:class:`CoordinatorSnapshot` (graph at the exposure floor, non-retired
decisions, world counter, per-SO flush seqs) and rotates the JSONL log to
a suffix, crash-safely via an atomic manifest swap. Restart then loads
snapshot + suffix instead of replaying the whole history, and runtimes GC
their fragment stores below the durable floor.
"""
from .compact import CheckpointCrash, CompactingLog, FAILPOINTS, read_durable_log
from .snapshot import (
    SNAPSHOT_VERSION,
    CoordinatorSnapshot,
    decode_manifest,
    decode_snapshot,
    encode_manifest,
    encode_snapshot,
)

__all__ = [
    "CheckpointCrash",
    "CompactingLog",
    "CoordinatorSnapshot",
    "FAILPOINTS",
    "SNAPSHOT_VERSION",
    "decode_manifest",
    "decode_snapshot",
    "encode_manifest",
    "encode_snapshot",
    "read_durable_log",
]
