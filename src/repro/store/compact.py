"""CompactingLog — the coordinator's durable store: a JSONL write-ahead log
with atomic snapshot + log-rotation checkpoints (DESIGN.md §11).

Layout, for a base path ``<p>`` (e.g. ``coord/shard0.jsonl``):

* generation 0 (the pre-snapshot legacy layout): the WAL is ``<p>`` itself,
  there is no snapshot and no manifest — a seed-era log directory recovers
  unchanged;
* generation ``N >= 1``: snapshot ``<p>.snap.N``, WAL ``<p>.wal.N``, and a
  manifest ``<p>.manifest`` naming ``N``.

``checkpoint(blob)`` is crash-safe by construction: the snapshot is written
to a temp file, fsynced, renamed into place and the directory fsynced;
a fresh empty WAL is created; only then is the manifest atomically swapped
(temp + fsync + rename). The manifest swap is the *commit point* — a crash
at any earlier step leaves the old manifest naming the old generation,
whose snapshot and WAL are untouched (appends during a checkpoint are
serialized out by the coordinator lock, and the old WAL keeps receiving
them until the swap), so recovery sees either the full old generation or
the full new one, never a mix. Orphaned files from an interrupted
checkpoint are deleted on the next open/checkpoint. The exhaustive
crash-point test (``tests/test_store.py``) kills the checkpoint after
every step via ``_failpoint`` and asserts recovery from every prefix.

Replay order is ``(snapshot blob, suffix records)``: the caller restores
state from the snapshot, then applies the JSONL suffix (same torn-tail
tolerance as the seed-era log).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import List, Optional, Tuple

from .snapshot import decode_manifest, encode_manifest


class CheckpointCrash(RuntimeError):
    """Raised by ``checkpoint(_failpoint=...)`` to simulate a crash after
    the named step completed (test-only; the instance must be discarded)."""


#: ordered checkpoint steps a crash can land after (see checkpoint())
FAILPOINTS = (
    "begin",
    "snap-tmp-written",
    "snap-renamed",
    "snap-dir-synced",
    "wal-created",
    "manifest-tmp-written",
    "manifest-swapped",
    "rotated",
)


def _fsync_dir(path: Path) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open support
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# -- layout resolution + parsing, shared by CompactingLog.replay() and the  --
# -- read-only helper below: one implementation, one torn-tail semantics   --
def _manifest_path(base: Path) -> Path:
    return base.with_name(base.name + ".manifest")


def _wal_path(base: Path, gen: int) -> Path:
    return base if gen == 0 else base.with_name(f"{base.name}.wal.{gen}")


def _snap_path(base: Path, gen: int) -> Path:
    return base.with_name(f"{base.name}.snap.{gen}")


def _read_generation(base: Path) -> int:
    try:
        return decode_manifest(_manifest_path(base).read_bytes())
    except FileNotFoundError:
        return 0
    # a corrupt manifest is NOT silently treated as generation 0: the swap
    # is atomic, so corruption means real storage damage and a gen-0
    # fallback could resurrect long-compacted state. Let it raise.


def _read_jsonl(path: Path) -> List[dict]:
    out: List[dict] = []
    try:
        raw = path.read_bytes()
    except FileNotFoundError:
        return out
    for line in raw.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line.decode()))
        except Exception:
            break  # torn tail write: ignore the partial record
    return out


class CompactingLog:
    """Synchronous durable appends + atomic snapshot/rotate checkpoints.

    The interface the coordinator needs is unchanged from the seed-era
    ``CoordinatorLog`` (ordered, durable ``append`` + full ``replay``) plus
    ``checkpoint`` and the size counters that drive auto-compaction; in
    production the same interface maps onto Netherite-style partition
    checkpoints over a commit log (paper Fig. 8).
    """

    def __init__(
        self,
        path: Path,
        *,
        checkpoint_records: Optional[int] = 256,
        checkpoint_bytes: int = 1 << 20,
    ) -> None:
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._manifest = _manifest_path(self.path)
        self.checkpoint_records = checkpoint_records
        self.checkpoint_bytes = checkpoint_bytes
        self.generation = _read_generation(self.path)
        self._cleanup_stale()
        wal = self._wal_path(self.generation)
        self._fh = open(wal, "a+b")
        # suffix length since the last checkpoint, for the auto trigger
        with open(wal, "rb") as f:
            self._records = sum(1 for _ in f)
        self._wal_bytes = wal.stat().st_size

    # -- layout ---------------------------------------------------------- #
    def _wal_path(self, gen: int) -> Path:
        return _wal_path(self.path, gen)

    def _snap_path(self, gen: int) -> Path:
        return _snap_path(self.path, gen)

    def _cleanup_stale(self) -> None:
        """Delete files of every generation but the current one — leftovers
        of a checkpoint that crashed before (orphans) or after (previous
        generation) its manifest swap."""
        keep = {self._wal_path(self.generation), self._snap_path(self.generation)}
        if self.generation > 0:
            stale = [self.path]  # the legacy gen-0 WAL
        else:
            stale = []
        stale += list(self.path.parent.glob(f"{self.path.name}.snap.*"))
        stale += list(self.path.parent.glob(f"{self.path.name}.wal.*"))
        stale += list(self.path.parent.glob(f"{self.path.name}.manifest.tmp"))
        for p in stale:
            if p not in keep:
                try:
                    p.unlink()
                except OSError:
                    pass

    # -- WAL ------------------------------------------------------------- #
    def append(self, record: dict) -> None:
        data = json.dumps(record).encode() + b"\n"
        self._fh.write(data)
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._records += 1
        self._wal_bytes += len(data)

    def should_checkpoint(self) -> bool:
        if self.checkpoint_records is None:
            return False
        return (
            self._records >= self.checkpoint_records
            or self._wal_bytes >= self.checkpoint_bytes
        )

    @property
    def records_since_checkpoint(self) -> int:
        return self._records

    def replay(self) -> Tuple[Optional[bytes], List[dict]]:
        """(snapshot blob or None, JSONL suffix records)."""
        blob: Optional[bytes] = None
        if self.generation > 0:
            # the manifest names this generation, so its snapshot was fully
            # written + fsynced before the swap; a read failure here is
            # storage corruption and must fail recovery loudly.
            blob = self._snap_path(self.generation).read_bytes()
        return blob, _read_jsonl(self._wal_path(self.generation))

    # -- checkpoint ------------------------------------------------------ #
    def checkpoint(self, snapshot_blob: bytes, *, _failpoint: Optional[str] = None) -> int:
        """Atomically install ``snapshot_blob`` as the new recovery base and
        rotate the WAL. Returns the new generation. Callers must serialize
        this with ``append`` (the coordinator holds its lock across both).

        ``_failpoint`` (test-only) raises :class:`CheckpointCrash` after the
        named step, simulating a process kill at that exact prefix.

        ``checkpoint_records=None`` disables compaction *entirely* — this
        method is then a no-op returning the current generation, so the
        contract is owned by the store, not re-checked at every call site
        (the snapshot-vs-replay differential's full-replay side depends on
        a disabled store never rotating).
        """
        if self.checkpoint_records is None:
            return self.generation

        def crash(step: str) -> None:
            if _failpoint == step:
                raise CheckpointCrash(step)

        crash("begin")
        gen = self.generation + 1
        snap, wal = self._snap_path(gen), self._wal_path(gen)
        tmp = snap.with_name(snap.name + ".tmp")
        # 1. durable snapshot under a temp name
        with open(tmp, "wb") as f:
            f.write(snapshot_blob)
            f.flush()
            os.fsync(f.fileno())
        crash("snap-tmp-written")
        # 2. publish the snapshot file (atomic), then make the name durable
        os.replace(tmp, snap)
        crash("snap-renamed")
        _fsync_dir(self.path.parent)
        crash("snap-dir-synced")
        # 3. fresh empty WAL for the new generation
        new_fh = open(wal, "a+b")
        try:
            _fsync_dir(self.path.parent)
            crash("wal-created")
            # 4. COMMIT: atomically swap the manifest to the new generation
            mtmp = self._manifest.with_name(self._manifest.name + ".tmp")
            with open(mtmp, "wb") as f:
                f.write(encode_manifest(gen))
                f.flush()
                os.fsync(f.fileno())
            crash("manifest-tmp-written")
            os.replace(mtmp, self._manifest)
            _fsync_dir(self.path.parent)
        except BaseException:
            # pre-commit failure (or a test failpoint): the old generation
            # is still the manifest's truth and its WAL handle stays active;
            # drop the would-be new WAL handle so nothing writes to it.
            new_fh.close()
            raise
        # -- committed: everything below is post-crash-safe cleanup -------- #
        old_gen = self.generation
        self.generation = gen
        old_fh, self._fh = self._fh, new_fh
        old_fh.close()
        self._records = 0
        self._wal_bytes = 0
        try:
            crash("manifest-swapped")
            for p in (self._wal_path(old_gen), self._snap_path(old_gen)):
                try:
                    p.unlink()
                except OSError:
                    pass
            crash("rotated")
        except CheckpointCrash:
            raise
        return gen

    def close(self) -> None:
        try:
            self._fh.close()
        except Exception:
            pass


# --------------------------------------------------------------------------- #
# read-side helper for external checkers (sim/invariants.py)                  #
# --------------------------------------------------------------------------- #
def read_durable_log(path: Path) -> Tuple[int, Optional[bytes], List[dict]]:
    """Read a (possibly rotated) coordinator log without opening it for
    append: ``(generation, snapshot blob or None, suffix records)`` — the
    exact layout resolution and torn-tail semantics of ``replay()``, via
    the shared helpers above (external checkers must never drift from what
    recovery itself would read)."""
    path = Path(path)
    gen = _read_generation(path)
    blob = _snap_path(path, gen).read_bytes() if gen > 0 else None
    return gen, blob, _read_jsonl(_wal_path(path, gen))
