"""DurableRuntime (repro.durable) — the synchronous durable-execution
baseline: per-action synchronous persistence + report-ack, crash-loss-free
acks, protocol interop with speculative peers, and the runtime= threading
through LocalCluster / NetCluster / SimCluster.
"""
from __future__ import annotations

import pytest

from conftest import settle
from repro.core import LocalCluster
from repro.core.runtime import DSEConfig
from repro.services.counter import CounterStateObject
from repro.services.kv_store import SpeculativeKVStore
from repro.services.workflow import WorkflowEngine


class TestDurableSemantics:
    def test_every_action_synchronously_durable(self, tmp_path):
        with LocalCluster(tmp_path / "c", runtime="durable") as c:
            ctr = c.add("ctr", lambda: CounterStateObject(tmp_path / "so"))
            assert ctr.runtime.kind == "durable"
            for i in range(1, 4):
                v, h = ctr.increment(None)
                st = ctr.runtime.stats()
                # the ack is already durable — no group-commit wait involved
                assert st["committed"] >= i, st
                # and the emitted header references a durable vertex
                (dep,) = h.deps
                assert dep.version <= st["committed"]

    def test_crash_never_loses_acked_state(self, tmp_path):
        """THE oracle property: under DSE a never-persisted ack rolls back;
        under the durable baseline every ack survives any crash."""
        with LocalCluster(tmp_path / "c", runtime="durable") as c:
            ctr = c.add("ctr", lambda: CounterStateObject(tmp_path / "so"))
            acks = [ctr.increment(None)[0] for _ in range(5)]
            c.kill("ctr")
            c.refresh_all()
            assert c.get("ctr").value == acks[-1] == 5

    def test_speculative_peer_rolls_back_durable_does_not(self, tmp_path):
        """Mixed deployment: a durable producer's acks survive while the
        speculative consumer that consumed them recovers per protocol."""
        with LocalCluster(tmp_path / "c", refresh_interval=None, group_commit_interval=99) as c:
            prod = c.add(
                "prod", lambda: CounterStateObject(tmp_path / "p"), runtime="durable"
            )
            cons = c.add("cons", lambda: CounterStateObject(tmp_path / "q"))  # dse
            assert (prod.runtime.kind, cons.runtime.kind) == ("durable", "dse")
            for _ in range(3):
                v, h = prod.increment(None)
                cons.increment(h)
            assert cons.value == 3
            c.kill("cons")
            c.refresh_all()
            # consumer lost its speculative (never-persisted) increments;
            # the durable producer lost nothing and keeps serving
            assert c.get("cons").value == 0
            assert prod.increment(None)[0] == 4

    def test_workflow_on_durable_runtime(self, tmp_path):
        with LocalCluster(tmp_path / "c", runtime="durable") as c:
            kv = c.add("kv", lambda: SpeculativeKVStore(tmp_path / "kv"))
            kv.stock("item", 10)
            wf = c.add("wf", lambda: WorkflowEngine(tmp_path / "wf"))
            steps = [
                (lambda h, s=s: kv.try_reserve("item", f"w:{s}", h)) for s in range(3)
            ]
            out = wf.run_workflow("w", steps)
            assert out is not None and out[0] == [True, True, True]
            # crash both: everything acked must survive
            c.kill("wf")
            c.kill("kv")
            c.refresh_all()
            assert c.get("wf").workflow_state("w")["status"] == "done"
            v, _ = c.get("kv").get("inv:item")
            assert v == "7"

    def test_try_reserve_idempotent_by_owner(self, tmp_path):
        """Retried activity contract: re-applying a surviving reservation
        acks again without double-decrementing."""
        with LocalCluster(tmp_path / "c") as c:
            kv = c.add("kv", lambda: SpeculativeKVStore(tmp_path / "kv"))
            kv.stock("item", 2)
            assert kv.try_reserve("item", "w:0")[0] is True
            assert kv.try_reserve("item", "w:0")[0] is True  # retry, same owner
            v, _ = kv.get("inv:item")
            assert v == "1"

    def test_rejected_report_does_not_ack(self, tmp_path):
        """Ack-vs-ingest gap (code-review regression): a report delivered
        AFTER a decision already invalidated its vertex is silently dropped
        by coordinator ingest — it must NOT count as an admission ack. The
        durable commit fails the request (RolledBackError) instead of
        exposing state that the pending decision will roll back."""
        from repro.core.sthread import RolledBackError

        with LocalCluster(
            tmp_path / "c", refresh_interval=None, group_commit_interval=99
        ) as c:
            a = c.add(
                "a", lambda: CounterStateObject(tmp_path / "a"), runtime="durable"
            )
            c.add("b", lambda: CounterStateObject(tmp_path / "b"))
            assert a.increment(None)[0] == 1  # committed + admitted: label 1
            real = a.runtime.coordinator

            class DecideThenDeliver:
                """Transport model of the race: b's failure decision is
                computed while a's next report is still crossing the
                fabric, so the report lands already-invalidated."""

                armed = True

                def report(self, so_id, reports):
                    if self.armed:
                        self.armed = False
                        c.kill("b")  # decision targets a at its ingested v1
                    return real.report(so_id, reports)

                def __getattr__(self, name):
                    return getattr(real, name)

            a.runtime.coordinator = DecideThenDeliver()
            with pytest.raises(RolledBackError):
                a.increment(None)  # label 2: delivered but rejected
            # the rejection was counted server-side, and a recovers to the
            # consistent prefix and keeps serving
            assert a.runtime.world == 1
            assert a.value == 1
            assert a.increment(None)[0] == 2

    def test_report_returns_rejected_vertices(self, tmp_path):
        """Coordinator.report's return value is the admission ack: vertices
        an existing decision invalidates come back, admitted ones do not."""
        from repro.core.ids import PersistReport, RollbackDecision, Vertex

        with LocalCluster(tmp_path / "c") as c:
            coord = c.coordinator
            coord._note_decision(RollbackDecision(fsn=1, failed="x", targets={"x": 1}))
            ok = PersistReport(Vertex("x", 0, 1), (), seq=0)
            dead = PersistReport(Vertex("x", 0, 5), (), seq=1)
            assert coord.report("x", [ok, dead]) == [dead.vertex]
            assert coord.report("x", [ok]) == []  # seq-deduped, still admitted

    def test_unknown_runtime_rejected(self, tmp_path):
        so = CounterStateObject(tmp_path / "so")
        with LocalCluster(tmp_path / "c") as c:
            cfg = DSEConfig(so_id="x", coordinator=c.coordinator, runtime="nope")
            with pytest.raises(ValueError, match="unknown runtime"):
                so.Connect(cfg)


class TestDurableOverFabric:
    def test_durable_commit_pays_transport_roundtrip(self, tmp_path):
        """Over NetCluster the durable commit blocks on the report RPC
        through the fabric; acks still survive a crash."""
        from repro.net import NetCluster

        with NetCluster(tmp_path / "c", n_shards=2, runtime="durable") as c:
            ctr = c.add("ctr", lambda: CounterStateObject(tmp_path / "so"))
            for _ in range(3):
                c.send(None, "ctr", "increment", None)
            sent_before = c.transport.stats()["sent"]
            assert sent_before > 0  # report traffic crossed the fabric
            c.kill("ctr")
            assert settle(
                lambda: c.get("ctr").value == 3, cluster=c, timeout=10.0
            ), c.get("ctr").value

    def test_sim_cluster_threads_runtime(self, tmp_path):
        from repro.sim import SimCluster

        sim = SimCluster(tmp_path / "s", seed=3, n_shards=2, runtime="durable")

        def scenario(sim):
            sim.add("ctr", lambda: CounterStateObject(sim.root / "so"))
            out = sim.send(None, "ctr", "increment", None)
            assert out is not None
            return {
                "kind": sim.get("ctr").runtime.kind,
                "committed": sim.get("ctr").runtime.stats()["committed"],
            }

        res = sim.run(scenario)
        assert res.value["kind"] == "durable"
        assert res.value["committed"] >= 1
