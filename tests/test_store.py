"""repro.store unit tests (DESIGN.md §11): snapshot/manifest codec strictness,
crash-safe checkpointing (exhaustive crash-point recovery), decision
retirement soundness, and coordinator snapshot+suffix ≡ full-replay
equivalence. The whole-system counterpart runs under deterministic
simulation (``snapshot_recovery_*`` scenarios, pinned in
``tests/scenarios/regression_seeds.json``).
"""
from __future__ import annotations

import pytest

from repro.core.coordinator import Coordinator
from repro.core.ids import (
    PersistReport,
    RollbackDecision,
    Vertex,
    decode_decision,
    encode_decision,
)
from repro.store import (
    FAILPOINTS,
    CheckpointCrash,
    CompactingLog,
    CoordinatorSnapshot,
    decode_manifest,
    decode_snapshot,
    encode_manifest,
    encode_snapshot,
)


def rich_snapshot() -> CoordinatorSnapshot:
    return CoordinatorSnapshot(
        fsn=7,
        retired_upto=3,
        members=["a", "b", "naïve-so"],
        decisions=[
            RollbackDecision(4, "a", {"a": 2, "b": 3}, lost={"a": 5, "b": 3}),
            RollbackDecision(7, "b", {"a": -1, "b": 0}),  # legacy: no lost
        ],
        graph={
            "a": [(2, []), (5, [("b", 3), ("naïve-so", 0)])],
            "b": [(3, [("a", 2)])],
            "naïve-so": [(0, [])],
        },
        floor={"a": 2, "b": 3, "naïve-so": -1},
        report_seen={"a": {(0, 1), (4, 0)}, "b": {(0, 0)}},
    )


class TestSnapshotCodec:
    def test_round_trip(self):
        s = rich_snapshot()
        s2 = decode_snapshot(encode_snapshot(s))
        assert (
            s2.fsn,
            s2.retired_upto,
            s2.members,
            s2.decisions,
            s2.graph,
            s2.floor,
            s2.report_seen,
        ) == (s.fsn, s.retired_upto, sorted(s.members), s.decisions, s.graph, s.floor, s.report_seen)

    def test_empty_round_trip(self):
        s2 = decode_snapshot(encode_snapshot(CoordinatorSnapshot()))
        assert s2 == CoordinatorSnapshot()

    def test_every_truncated_prefix_rejected(self):
        blob = encode_snapshot(rich_snapshot())
        for i in range(len(blob)):
            with pytest.raises(ValueError):
                decode_snapshot(blob[:i])

    def test_trailing_garbage_rejected(self):
        blob = encode_snapshot(rich_snapshot())
        with pytest.raises(ValueError):
            decode_snapshot(blob + b"\x00")

    def test_unknown_version_rejected(self):
        blob = bytearray(encode_snapshot(CoordinatorSnapshot()))
        # layout: magic, kind, string table (empty => one 0 byte), version
        blob[3] = 99
        with pytest.raises(ValueError, match="version"):
            decode_snapshot(bytes(blob))

    def test_manifest_round_trip_and_strictness(self):
        for gen in (0, 1, 300):
            assert decode_manifest(encode_manifest(gen)) == gen
        blob = encode_manifest(300)
        for i in range(len(blob)):
            with pytest.raises(ValueError):
                decode_manifest(blob[:i])
        with pytest.raises(ValueError):
            decode_manifest(blob + b"\x01")

    def test_decision_lost_round_trip_binary_and_json(self):
        d = RollbackDecision(5, "x", {"x": 1, "y": 2}, lost={"x": 9, "y": 2})
        assert decode_decision(encode_decision(d)) == d
        assert RollbackDecision.from_json(d.to_json()) == d
        legacy = RollbackDecision(5, "x", {"x": 1})
        assert "lost" not in legacy.to_json()  # old readers stay compatible
        assert RollbackDecision.from_json(legacy.to_json()) == legacy


RECORDS = [
    {"type": "member", "so_id": "a"},
    {"type": "member", "so_id": "b"},
    {"type": "decision", "fsn": 1, "failed": "a", "targets": {"a": 0, "b": 0}, "lost": {"a": 2, "b": 1}},
    {"type": "decision", "fsn": 2, "failed": "b", "targets": {"a": 3, "b": 1}, "lost": {"a": 3, "b": 4}},
]


class TestCompactingLogCrashPoints:
    """The compactor's contract: a crash after ANY step recovers either the
    whole old generation or the whole new one — never a mix, never a loss."""

    def _fill(self, log: CompactingLog, records=RECORDS) -> None:
        for rec in records:
            log.append(rec)

    @pytest.mark.parametrize("failpoint", FAILPOINTS)
    @pytest.mark.parametrize("warm", [False, True], ids=["gen0", "gen1"])
    def test_every_crash_prefix_recovers(self, tmp_path, failpoint, warm):
        base = tmp_path / "log.jsonl"
        old_blob = None
        # huge threshold: explicit checkpoints allowed, auto-trigger quiet
        log = CompactingLog(base, checkpoint_records=10**9)
        if warm:
            # start from generation 1 so the crash also interrupts the
            # deletion of a real previous generation
            old_blob = encode_snapshot(CoordinatorSnapshot(fsn=1, members=["z"]))
            log.checkpoint(old_blob)
        self._fill(log)
        new_blob = encode_snapshot(rich_snapshot())
        with pytest.raises(CheckpointCrash):
            log.checkpoint(new_blob, _failpoint=failpoint)
        log.close()

        recovered = CompactingLog(base)  # the restarted process
        # interrupted-checkpoint orphans (snap/wal/manifest temp files and
        # uncommitted generations) are swept on open
        assert not list(tmp_path.glob("*.tmp"))
        blob, suffix = recovered.replay()
        committed = failpoint in ("manifest-swapped", "rotated")
        if committed:
            assert blob == new_blob
            assert suffix == []
        else:
            # old generation intact: snapshot AND the full record suffix
            assert blob == old_blob
            assert suffix == RECORDS
        # the store must still be fully operational after the crash
        recovered.append({"type": "member", "so_id": "late"})
        recovered.checkpoint(new_blob)
        recovered.append({"type": "member", "so_id": "later"})
        recovered.close()
        final = CompactingLog(base)
        blob, suffix = final.replay()
        assert blob == new_blob
        assert suffix == [{"type": "member", "so_id": "later"}]
        final.close()

    def test_stale_generations_cleaned_after_commit(self, tmp_path):
        base = tmp_path / "log.jsonl"
        log = CompactingLog(base)
        self._fill(log)
        log.checkpoint(encode_snapshot(CoordinatorSnapshot(fsn=1)))
        log.checkpoint(encode_snapshot(CoordinatorSnapshot(fsn=2)))
        log.close()
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["log.jsonl.manifest", "log.jsonl.snap.2", "log.jsonl.wal.2"]

    def test_auto_trigger_thresholds(self, tmp_path):
        log = CompactingLog(tmp_path / "l.jsonl", checkpoint_records=3)
        assert not log.should_checkpoint()
        self._fill(log, RECORDS[:3])
        assert log.should_checkpoint()
        log.checkpoint(encode_snapshot(CoordinatorSnapshot()))
        assert not log.should_checkpoint()
        disabled = CompactingLog(tmp_path / "l2.jsonl", checkpoint_records=None)
        self._fill(disabled, RECORDS)
        assert not disabled.should_checkpoint()
        # the store OWNS the disabled contract: even an explicit checkpoint
        # is a no-op (the snapshot-vs-replay oracle's full-replay side
        # depends on a disabled store never rotating)
        assert disabled.checkpoint(encode_snapshot(CoordinatorSnapshot())) == 0
        assert disabled.replay() == (None, RECORDS)
        log.close()
        disabled.close()

    def test_torn_wal_tail_tolerated_after_rotation(self, tmp_path):
        base = tmp_path / "log.jsonl"
        log = CompactingLog(base)
        blob = encode_snapshot(CoordinatorSnapshot(fsn=3))
        log.checkpoint(blob)
        log.append(RECORDS[0])
        log.close()
        with open(tmp_path / "log.jsonl.wal.1", "ab") as f:
            f.write(b'{"type": "member", "so_id": "tor')  # torn write
        blob2, suffix = CompactingLog(base).replay()
        assert blob2 == blob
        assert suffix == [RECORDS[0]]


class TestDecisionRetirement:
    """The compactor's retirement rule (DESIGN.md §11): a decision leaves
    the durable cut only when every target's exposure floor has strictly
    passed its lost window; prefix-only; legacy (lost-free) decisions are
    immortal."""

    def _coord(self, tmp_path, **kw) -> Coordinator:
        return Coordinator(tmp_path / "c.jsonl", **kw)

    def _checkpoint_at(self, coord: Coordinator, floor) -> None:
        with coord._lock:
            coord._checkpoint_locked(dict(floor))

    def test_floor_must_strictly_pass_lost(self, tmp_path):
        coord = self._coord(tmp_path)
        d1 = RollbackDecision(1, "a", {"a": 2, "b": 3}, lost={"a": 5, "b": 3})
        with coord._lock:
            coord._note_decision(d1)
        self._checkpoint_at(coord, {"a": 5, "b": 4})  # floor == lost["a"]
        assert coord.stats()["decisions"] == 1  # retained
        self._checkpoint_at(coord, {"a": 6, "b": 4})  # strictly past both
        st = coord.stats()
        assert st["decisions"] == 0 and st["retired_upto"] == 1
        coord.close()

    def test_prefix_only_retirement(self, tmp_path):
        coord = self._coord(tmp_path)
        d1 = RollbackDecision(1, "a", {"a": 0}, lost={"a": 9})  # floor not past
        d2 = RollbackDecision(2, "b", {"b": 0}, lost={"b": 1})  # floor past
        with coord._lock:
            coord._note_decision(d1)
            coord._note_decision(d2)
        self._checkpoint_at(coord, {"a": 4, "b": 7})
        st = coord.stats()
        # d2 is individually dead but must wait behind d1: the durable cut
        # records one retired_upto watermark, not a sieve
        assert st["decisions"] == 2 and st["retired_upto"] == 0
        coord.close()

    def test_legacy_decisions_never_retire(self, tmp_path):
        coord = self._coord(tmp_path)
        with coord._lock:
            coord._note_decision(RollbackDecision(1, "a", {"a": 0}))  # no lost
        self._checkpoint_at(coord, {"a": 99})
        assert coord.stats()["decisions"] == 1
        coord.close()

    def test_retirement_survives_restart(self, tmp_path):
        coord = self._coord(tmp_path)
        with coord._lock:
            coord._note_decision(RollbackDecision(1, "a", {"a": 0}, lost={"a": 1}))
        self._checkpoint_at(coord, {"a": 5})
        assert coord.stats()["retired_upto"] == 1
        coord.close()
        coord2 = self._coord(tmp_path)
        st = coord2.stats()
        assert st["retired_upto"] == 1 and st["fsn"] == 1 and st["decisions"] == 0
        coord2.close()


class TestCoordinatorSnapshotRecovery:
    """snapshot + suffix must recover the same coordinator a full replay
    builds — driven through the public participant API twin-style."""

    def _drive(self, coord: Coordinator, checkpoint_midway: bool) -> None:
        coord.connect("a", [])
        coord.connect("b", [])
        coord.report("a", [PersistReport(Vertex("a", 0, 0), (), seq=0)])
        coord.report("b", [PersistReport(Vertex("b", 0, 0), (Vertex("a", 0, 0),), seq=0)])
        # failure: "a" reconnects having lost nothing durable
        coord.connect("a", [PersistReport(Vertex("a", 0, 0), ())])
        if checkpoint_midway:
            coord.checkpoint()
        world = coord._world()
        coord.report("a", [PersistReport(Vertex("a", world, 1), (), seq=1)])
        coord.report("b", [PersistReport(Vertex("b", world, 1), (Vertex("a", world, 1),), seq=1)])

    def _recovered_view(self, coord: Coordinator):
        # a restarted coordinator serves boundaries only after resends
        world = coord._world()
        coord.receive_fragments("a", [PersistReport(Vertex("a", world, 1), ())])
        coord.receive_fragments(
            "b", [PersistReport(Vertex("b", world, 1), (Vertex("a", world, 1),))]
        )
        st = coord.stats()
        return (
            st["members"],
            st["fsn"],
            [d.to_json() for d in coord._all_decisions()],
            coord.current_boundary(),
            coord._graph.export_state(),
        )

    def test_snapshot_plus_suffix_equals_full_replay(self, tmp_path):
        twin = {}
        for name, checkpointed in (("plain", False), ("compacted", True)):
            # huge threshold: the explicit mid-drive checkpoint is the only one
            coord = Coordinator(tmp_path / f"{name}.jsonl", checkpoint_records=10**9)
            self._drive(coord, checkpoint_midway=checkpointed)
            coord.close()
            restarted = Coordinator(tmp_path / f"{name}.jsonl")
            twin[name] = self._recovered_view(restarted)
            restarted.close()
        assert twin["plain"] == twin["compacted"]

    def test_report_seen_survives_the_cut(self, tmp_path):
        """A pre-crash flush's transport retry landing after a snapshot
        recovery must still be single-counted (the durable cut carries the
        per-SO flush seqs)."""
        coord = Coordinator(tmp_path / "c.jsonl")
        coord.connect("a", [])
        r = PersistReport(Vertex("a", 0, 0), (), seq=0)
        coord.report("a", [r])
        coord.checkpoint()
        coord.close()
        coord2 = Coordinator(tmp_path / "c.jsonl")
        coord2.report("a", [r])  # the retry of the pre-crash delivery
        assert coord2.stats()["dup_reports_dropped"] == 1
        coord2.close()

    def test_stats_makes_no_graph_deep_copy(self, tmp_path):
        coord = Coordinator(tmp_path / "c.jsonl")
        coord.connect("a", [])
        coord.report("a", [PersistReport(Vertex("a", 0, 0), (), seq=0)])

        def boom():  # pragma: no cover - called means regression
            raise AssertionError("stats() must not deep-copy the graph")

        coord._graph.snapshot = boom
        st = coord.stats()
        assert st["graph_vertices"] == 1 and st["members"] == ["a"]
        coord.close()
