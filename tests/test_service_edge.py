"""Service-layer edge cases: broker multi-partition + multi-group pruning,
workflow resume driver, spec-log floor pruning, coordinator torn log tail."""
from __future__ import annotations

import time

from repro.core import Coordinator, LocalCluster

from conftest import wait_committed
from repro.services import EventBroker, SpeculativeKVStore, SpeculativeLog, WorkflowEngine


class TestBrokerPartitions:
    def test_multi_partition_round_trip(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        br = c.add(
            "br", lambda: EventBroker(tmp_path / "br", topics=["t"], partitions=3)
        )
        for part in range(3):
            offs, h = br.produce("t", [f"p{part}e{i}".encode() for i in range(4)], part=part)
            assert offs == [0, 1, 2, 3]
        for part in range(3):
            evs, h = br.consume("g", "t", part=part)
            assert [d for _, d in evs] == [f"p{part}e{i}".encode() for i in range(4)]
            br.ack("g", "t", 3, header=h, part=part)

    def test_prune_waits_for_slowest_group(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        br = c.add("br", lambda: EventBroker(tmp_path / "br2", topics=["t"]))
        _, h = br.produce("t", [b"a", b"b", b"c", b"d"])
        # both groups register (consume) before anyone acks
        e1, h1 = br.consume("fast", "t", header=h)
        e2, h2 = br.consume("slow", "t", max_n=2, header=h)
        br.ack("fast", "t", 3, header=h1)
        br.ack("slow", "t", 1, header=h2)
        assert wait_committed(br, br.runtime.maybe_persist(force=True))
        # only the prefix ACKED by BOTH groups skipped storage
        assert br.entries_skipped() == 2
        # and the slow group can still read its unacked events
        evs, _ = br.consume("slow", "t")
        assert [d for _, d in evs] == [b"c", b"d"]


class TestWorkflowResumeDriver:
    def test_pending_workflows_listed_and_resumable(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        kv = c.add("kv", lambda: SpeculativeKVStore(tmp_path / "kv"))
        kv.stock("item", 10)
        wf = c.add("wf", lambda: WorkflowEngine(tmp_path / "wf"))
        steps = [lambda hdr: kv.try_reserve("item", "w1", hdr)]
        # start but do not finish (external=False leaves it speculative)
        out = wf.run_workflow("w1", steps, external=False)
        assert out is not None
        # a fresh driver can discover nothing pending (w1 completed its only
        # step); run a 2-step workflow and interrupt by inspecting state
        assert wf.workflow_state("w1")["status"] == "done"
        assert "w1" not in wf.pending_workflows()


class TestSpecLogPrune:
    def test_floor_hides_old_versions_keeps_data(self, cluster_factory, tmp_path):
        # no background refresher: the steady-state boundary prune would
        # already collapse the listing (the very behaviour under test)
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        log = c.add("log", lambda: SpeculativeLog(tmp_path / "log"))
        for i in range(3):
            log.append(f"e{i}".encode())
            assert wait_committed(log, log.runtime.maybe_persist(force=True))
        before = [v for v, _ in log.core.list_versions()]
        assert len(before) >= 3  # the Connect floor + forced persists
        anchor = before[-2]  # prune at a real persisted label
        log.core.prune(anchor)
        # below-floor commit records drop from the listing (O(live)
        # reconnects, DESIGN.md §11) but the anchor — the greatest version
        # <= the floor — must stay listable (StateObject.Prune contract)
        versions = [v for v, _ in log.core.list_versions()]
        assert versions == [v for v in before if v >= anchor]
        assert anchor in versions and len(versions) < len(before)
        # restore chain from version >= floor still reads ALL data
        log.core.drop_memory()
        log.core.restore(max(versions))
        assert [d for _, d in log.core.scan(0)] == [b"e0", b"e1", b"e2"]


class TestCoordinatorLogTornTail:
    def test_torn_tail_write_ignored_on_replay(self, tmp_path):
        log_path = tmp_path / "coord.jsonl"
        coord = Coordinator(log_path)
        coord.connect("a", [])
        coord.close()
        with open(log_path, "ab") as f:
            f.write(b'{"type": "member", "so_id": "tor')  # torn write
        coord2 = Coordinator(log_path)
        assert coord2.stats()["members"] == ["a"]
        coord2.close()
