"""Sharded-coordinator + NetCluster tests (repro.net.sharded / .cluster):

* consistent-hash placement,
* cross-shard boundary merge (per-shard fixpoint == global fixpoint),
* decision broadcast replication to every shard log,
* shard restart refusing boundaries until its members resend fragments,
* coordinator restart + fragment resend over a lossy, laggy fabric
  (delayed / resent fragments), and
* the end-to-end acceptance scenario: recovery to a consistent boundary
  with 2 coordinator shards while SimTransport injects message loss and a
  healed partition.

The two lossy-fabric recovery tests run under deterministic simulation
(``repro.sim.SimCluster``): their latency/retry/settle waits are virtual,
so they cost milliseconds instead of wall seconds and replay identically
from their seed. ``test_e2e_recovery_with_shards_loss_and_healed_partition``
stays on the real clock as this module's wall-clock smoke test.
"""
from __future__ import annotations

import json

import pytest

from repro.core.ids import PersistReport, Vertex
from repro.net import HashRing, LinkSpec, NetCluster, ShardedCoordinator, SimTransport
from repro.sim import SimCluster

from conftest import make_counter, settle


def distinct_shard_ids(sc_or_ring, base: str = "p") -> tuple:
    """Two so_ids that consistent-hash to different shards."""
    lookup = sc_or_ring.shard_index if hasattr(sc_or_ring, "shard_index") else sc_or_ring.lookup
    first = f"{base}0"
    home = lookup(first)
    for i in range(1, 1000):
        cand = f"{base}{i}"
        if lookup(cand) != home:
            return first, cand
    raise AssertionError("ring maps everything to one shard")


def rep(so: str, version: int, deps=()) -> PersistReport:
    return PersistReport(Vertex(so, 0, version), tuple(Vertex(s, 0, v) for s, v in deps))


# --------------------------------------------------------------------------- #
# consistent hashing                                                           #
# --------------------------------------------------------------------------- #
class TestHashRing:
    def test_deterministic_across_instances(self):
        a = HashRing([0, 1, 2, 3])
        b = HashRing([0, 1, 2, 3])
        keys = [f"so-{i}" for i in range(200)]
        assert [a.lookup(k) for k in keys] == [b.lookup(k) for k in keys]

    def test_spreads_over_all_nodes(self):
        ring = HashRing([0, 1, 2, 3])
        owners = {ring.lookup(f"so-{i}") for i in range(200)}
        assert owners == {0, 1, 2, 3}

    def test_adding_a_node_moves_few_keys(self):
        keys = [f"so-{i}" for i in range(500)]
        before = {k: HashRing([0, 1, 2]).lookup(k) for k in keys}
        after = {k: HashRing([0, 1, 2, 3]).lookup(k) for k in keys}
        moved = sum(1 for k in keys if before[k] != after[k])
        # consistent hashing: ~1/4 of keys move, not ~3/4 (modulo would)
        assert moved < len(keys) // 2


# --------------------------------------------------------------------------- #
# sharded coordinator (driven directly, no transport)                          #
# --------------------------------------------------------------------------- #
class TestShardedCoordinator:
    def test_cross_shard_boundary_merge(self, tmp_path):
        sc = ShardedCoordinator(tmp_path / "sc", n_shards=2)
        p, q = distinct_shard_ids(sc)
        sc.connect(p, [])
        sc.connect(q, [])
        sc.report(p, [rep(p, 0)])
        sc.report(q, [rep(q, 0)])
        assert sc.current_boundary() == {p: 0, q: 0}
        # q@1 depends on p@1 which is not durable yet: the cross-shard
        # fixpoint must keep q at 0 even though q's OWN shard has q@1.
        sc.report(q, [rep(q, 1, deps=[(p, 1)])])
        assert sc.current_boundary()[q] == 0
        sc.report(p, [rep(p, 1)])
        assert sc.current_boundary() == {p: 1, q: 1}
        sc.close()

    def test_decision_broadcast_replicated_to_every_shard_log(self, tmp_path):
        sc = ShardedCoordinator(tmp_path / "sc", n_shards=3)
        p, q = distinct_shard_ids(sc)
        sc.connect(p, [])
        sc.connect(q, [])
        sc.report(p, [rep(p, 0)])
        sc.report(q, [rep(q, 0), rep(q, 1, deps=[(p, 1)])])
        # p fails having lost everything past v0: second connect => decision
        resp = sc.connect(p, [rep(p, 0)])
        assert resp.world == 1 and len(resp.decisions) == 1
        assert resp.decisions[0].targets[q] == 0  # cross-shard rollback
        for shard in sc.shards:
            records = [
                json.loads(line)
                for line in (tmp_path / "sc" / f"shard{shard.shard_id}.jsonl").read_text().splitlines()
            ]
            fsns = [r["fsn"] for r in records if r.get("type") == "decision"]
            assert fsns == [1], f"shard {shard.shard_id} missing the broadcast decision"
        sc.close()

    def test_shard_restart_refuses_boundary_until_members_resend(self, tmp_path):
        sc = ShardedCoordinator(tmp_path / "sc", n_shards=2)
        p, q = distinct_shard_ids(sc)
        sc.connect(p, [])
        sc.connect(q, [])
        sc.report(p, [rep(p, 0)])
        sc.report(q, [rep(q, 0)])
        before = sc.current_boundary()
        assert before is not None

        idx = sc.shard_index(q)
        sc.restart_shard(idx)
        assert sc.current_boundary() is None  # incomplete view: refuse
        assert sc.poll(q, 0).resend_fragments
        assert not sc.poll(p, 0).resend_fragments  # other shard unaffected
        sc.receive_fragments(q, [rep(q, 0)])
        after = sc.current_boundary()
        assert after is not None
        for so, wm in before.items():
            assert after[so] >= wm
        sc.close()

    def test_restarted_shard_catches_up_on_missed_decisions(self, tmp_path):
        sc = ShardedCoordinator(tmp_path / "sc", n_shards=2)
        p, q = distinct_shard_ids(sc)
        sc.connect(p, [])
        sc.connect(q, [])
        sc.report(p, [rep(p, 0)])
        sc.report(q, [rep(q, 0)])
        sc.connect(p, [rep(p, 0)])  # decision fsn=1 while both shards live
        # restart q's shard: replay must expose the decision (replicated log)
        shard = sc.restart_shard(sc.shard_index(q))
        assert [d.fsn for d in shard.replayed_decisions()] == [1]
        sc.receive_fragments(q, [rep(q, 0)])
        assert sc.poll(q, 0).decisions[0].fsn == 1
        sc.close()


# --------------------------------------------------------------------------- #
# NetCluster over a faulty fabric                                              #
# --------------------------------------------------------------------------- #
class TestNetClusterRecovery:
    def _cluster(self, tmp_path, link: LinkSpec, n_shards: int = 2, **kw) -> NetCluster:
        transport = SimTransport(
            seed=11, default_link=link, retry_timeout=0.01, call_timeout=3.0
        )
        kw.setdefault("refresh_interval", None)
        kw.setdefault("group_commit_interval", 0.005)
        return NetCluster(
            tmp_path / "cluster", transport=transport, n_shards=n_shards, **kw
        )

    def test_coordinator_restart_fragment_resend_over_lossy_fabric(self, tmp_path):
        """Satellite: a restarted (sharded) coordinator refuses boundary
        queries until every participant has resent fragments — with the
        resends themselves delayed, dropped, and retried by the fabric.
        Runs under deterministic simulation: the lossy retry storm and both
        settle loops elapse in virtual time."""
        sim = SimCluster(
            tmp_path,
            seed=11,
            n_shards=2,
            default_link=LinkSpec(
                latency_ms=0.2, jitter_ms=0.5, loss_prob=0.15, reorder_prob=0.2
            ),
            refresh_interval=None,
            group_commit_interval=0.005,
            call_timeout=3.0,
        )

        def scenario(sim: SimCluster):
            c = sim.cluster
            p_id, q_id = distinct_shard_ids(c.coordinator)
            c.add(p_id, make_counter(tmp_path, "p"))
            c.add(q_id, make_counter(tmp_path, "q"))
            _, h = c.send(None, p_id, "increment", None)
            c.send(None, q_id, "increment", h, by=5)
            assert sim.settle(lambda: (sim.boundary() or {}).get(q_id, -1) >= 1)
            before = sim.boundary()

            c.restart_coordinator()
            assert sim.boundary() is None  # all shards recovering
            # every poll answers resend_fragments=True until the (lossy,
            # delayed, retried) fragment resends from BOTH participants
            # arrive in full
            assert sim.settle(lambda: sim.boundary() is not None)
            after = sim.boundary()
            for so, wm in before.items():
                assert after[so] >= wm, "recovered view must be at least as fresh"

        sim.run(scenario, monitor_interval=None)

    def test_e2e_recovery_with_shards_loss_and_healed_partition(self, tmp_path):
        """Acceptance scenario: 2 coordinator shards, lossy fabric, a
        partition that cuts the coordinator off mid-workload and then heals,
        and a producer crash — the cluster must converge to one world and a
        consistent (consumer <= producer) recovered prefix, then keep
        serving new traffic."""
        link = LinkSpec(latency_ms=0.1, jitter_ms=0.3, loss_prob=0.05)
        # background refresher drives report/poll over the fabric; a huge
        # group-commit interval keeps persistence explicit so the partition-era
        # increments are genuinely speculative (lost on crash).
        c = self._cluster(
            tmp_path, link, refresh_interval=0.005, group_commit_interval=99
        )
        assert c.coordinator.n_shards == 2
        p_id, q_id = distinct_shard_ids(c.coordinator)
        producer = c.add(p_id, make_counter(tmp_path, "prod"))
        consumer = c.add(q_id, make_counter(tmp_path, "cons"))

        # durable prefix: 3 mirrored increments, persisted and barriered
        # into the global (cross-shard) boundary
        h = None
        for _ in range(3):
            _, h = c.send(None, p_id, "increment", None)
            c.send(None, q_id, "increment", h)
        producer.runtime.maybe_persist(force=True)
        t = consumer.Detach()
        t.Barrier(timeout=20.0)
        assert consumer.Merge(t)
        consumer.EndAction()
        durable_consumer = consumer.value
        assert durable_consumer == 3

        # partition the coordinator away; speculative traffic continues
        c.transport.partition({f"coord/{i}" for i in range(2)})
        for _ in range(2):
            _, h = c.send(None, p_id, "increment", None)
            c.send(None, q_id, "increment", h)
        assert consumer.value == 5  # speculative, not yet durable
        c.transport.heal()

        # producer crashes, losing its un-persisted tail
        c.kill(p_id)
        assert settle(lambda: c.get(q_id).runtime.world >= 1, cluster=c)

        new_consumer = c.get(q_id)
        new_producer = c.get(p_id)
        assert new_consumer.runtime.world == new_producer.runtime.world
        # consistent prefix: the consumer's state derives from the producer's,
        # so it must never be ahead of what the producer recovered
        assert new_consumer.value <= new_producer.value
        # the durable (barriered) prefix must have survived the crash
        assert new_producer.value >= 3
        assert new_consumer.value >= 3

        # global boundary converges for both shards' members
        assert settle(
            lambda: all(
                (c.coordinator.current_boundary() or {}).get(so, -1) >= 0
                for so in (p_id, q_id)
            ),
            cluster=c,
        )

        # cluster still serves traffic in the new epoch
        _, h2 = c.send(None, p_id, "increment", None)
        res = c.send(None, q_id, "increment", h2)
        assert res is not None
        st = c.transport.stats()
        assert st["dropped_loss"] > 0 and st["dropped_partition"] > 0
        c.shutdown()

    def test_service_traffic_exactly_once_under_loss(self, tmp_path):
        """services/* must pass under injected faults: every lossy RPC lands
        exactly once in the KV store's state. Runs under deterministic
        simulation — 20% loss means a retry storm whose backoff is all
        virtual time."""
        from repro.services.kv_store import SpeculativeKVStore

        sim = SimCluster(
            tmp_path,
            seed=11,
            n_shards=2,
            default_link=LinkSpec(latency_ms=0.1, loss_prob=0.2),
            refresh_interval=None,
            group_commit_interval=0.005,
            call_timeout=3.0,
        )

        def scenario(sim: SimCluster):
            sim.add("kv", lambda: SpeculativeKVStore(tmp_path / "kv"))
            sim.add("ctr", make_counter(tmp_path, "ctr"))
            total = 20
            h = None
            for i in range(total):
                v, h = sim.send(None, "ctr", "increment", h)
            assert v == total  # retries never double-incremented
            sim.send(None, "kv", "put", "k", "v1", h)
            got = sim.send(None, "kv", "get", "k", h)
            assert got[0] == "v1"

        result = sim.run(scenario, monitor_interval=None)
        assert result.transport_stats["dropped_loss"] > 0
