"""Binary wire codec round-trip tests (DESIGN.md §9).

Hypothesis drives header / metadata / report / decision / boundary blobs
through encode→decode — including non-ASCII so_ids, empty dep sets, and
negative watermarks — and pins the legacy-JSON fallback so blobs persisted
by pre-codec builds stay decodable forever.
"""
from __future__ import annotations

import json

from repro.core.ids import (
    Header,
    PersistReport,
    RollbackDecision,
    Vertex,
    WIRE_MAGIC,
    decode_boundary,
    decode_decision,
    decode_decisions,
    decode_metadata,
    decode_report,
    decode_reports,
    encode_boundary,
    encode_decision,
    encode_decisions,
    encode_metadata,
    encode_metadata_json,
    encode_report,
    encode_reports,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is optional (CI runs a without-matrix leg)
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    # so_ids: printable ASCII and non-ASCII (CJK, umlauts, emoji) — anything
    # a deployment might name a service; empty excluded (not a legal id).
    SO_IDS = st.text(
        alphabet=st.characters(blacklist_categories=("Cs",)), min_size=1, max_size=24
    )
    VERSIONS = st.integers(min_value=-1, max_value=2**40)
    WORLDS = st.integers(min_value=0, max_value=2**20)

    VERTICES = st.builds(Vertex, so_id=SO_IDS, world=WORLDS, version=VERSIONS)
    HEADERS = st.builds(
        lambda vs: Header(frozenset(vs)), st.lists(VERTICES, max_size=8)
    )
    REPORTS = st.builds(
        PersistReport, vertex=VERTICES, deps=st.lists(VERTICES, max_size=8).map(tuple)
    )
    DECISIONS = st.builds(
        RollbackDecision,
        fsn=st.integers(min_value=0, max_value=2**20),
        failed=SO_IDS,
        targets=st.dictionaries(SO_IDS, VERSIONS, max_size=8),
    )

    @settings(max_examples=200, deadline=None)
    @given(h=HEADERS)
    def test_header_round_trip(h):
        raw = h.encode()
        assert raw[0] == WIRE_MAGIC
        assert Header.decode(raw) == h

    @settings(max_examples=200, deadline=None)
    @given(
        world=WORLDS,
        version=VERSIONS,
        deps=st.lists(VERTICES, max_size=8),
        user=st.binary(max_size=64),
    )
    def test_metadata_round_trip(world, version, deps, user):
        raw = encode_metadata(world, version, deps, user=user)
        assert decode_metadata(raw) == (world, version, tuple(deps), user)

    @settings(max_examples=200, deadline=None)
    @given(r=REPORTS)
    def test_report_round_trip(r):
        assert decode_report(encode_report(r)) == r

    @settings(max_examples=100, deadline=None)
    @given(rs=st.lists(REPORTS, max_size=12))
    def test_report_batch_round_trip(rs):
        assert decode_reports(encode_reports(rs)) == rs

    @settings(max_examples=100, deadline=None)
    @given(ds=st.lists(DECISIONS, max_size=8))
    def test_decision_round_trip(ds):
        assert decode_decisions(encode_decisions(ds)) == ds
        for d in ds:
            assert decode_decision(encode_decision(d)) == d

    @settings(max_examples=100, deadline=None)
    @given(b=st.dictionaries(SO_IDS, VERSIONS, max_size=12))
    def test_boundary_round_trip(b):
        assert decode_boundary(encode_boundary(b)) == b

    @settings(max_examples=100, deadline=None)
    @given(
        world=WORLDS,
        version=VERSIONS,
        deps=st.lists(VERTICES, max_size=6),
        user=st.binary(max_size=32),
    )
    def test_metadata_legacy_json_fallback(world, version, deps, user):
        """Blobs persisted by pre-codec builds (JSON, hex-doubled user
        bytes) must decode identically forever — DESIGN.md §9."""
        raw = encode_metadata_json(world, version, deps, user=user)
        assert raw[:1] == b"{"
        assert decode_metadata(raw) == (world, version, tuple(deps), user)

    @settings(max_examples=100, deadline=None)
    @given(h=HEADERS)
    def test_header_legacy_json_fallback(h):
        legacy = json.dumps(sorted(v.to_json() for v in h.deps)).encode()
        assert Header.decode(legacy) == h


def test_seeded_round_trip_sweep():
    """Deterministic PRNG sweep over the same blob space — real coverage on
    the without-hypothesis CI leg and in local quick runs."""
    import random

    rng = random.Random(20260729)
    alphabet = "abzü注文🦜-/  \x00"

    def so_id():
        return "".join(rng.choice(alphabet) for _ in range(rng.randint(1, 12)))

    def vertex():
        return Vertex(so_id(), rng.randint(0, 2**20), rng.randint(-1, 2**40))

    for _ in range(300):
        h = Header(frozenset(vertex() for _ in range(rng.randint(0, 6))))
        assert Header.decode(h.encode()) == h
        r = PersistReport(vertex(), tuple(vertex() for _ in range(rng.randint(0, 6))))
        assert decode_report(encode_report(r)) == r
        rs = [
            PersistReport(vertex(), tuple(vertex() for _ in range(rng.randint(0, 4))))
            for _ in range(rng.randint(0, 8))
        ]
        assert decode_reports(encode_reports(rs)) == rs
        world, version = rng.randint(0, 2**20), rng.randint(-1, 2**40)
        deps = [vertex() for _ in range(rng.randint(0, 6))]
        user = bytes(rng.randint(0, 255) for _ in range(rng.randint(0, 48)))
        assert decode_metadata(encode_metadata(world, version, deps, user)) == (
            world,
            version,
            tuple(deps),
            user,
        )
        assert decode_metadata(encode_metadata_json(world, version, deps, user)) == (
            world,
            version,
            tuple(deps),
            user,
        )
        d = RollbackDecision(
            fsn=rng.randint(0, 2**20),
            failed=so_id(),
            targets={so_id(): rng.randint(-1, 2**30) for _ in range(rng.randint(0, 5))},
        )
        assert decode_decision(encode_decision(d)) == d
        b = {so_id(): rng.randint(-1, 2**30) for _ in range(rng.randint(0, 8))}
        assert decode_boundary(encode_boundary(b)) == b


def test_explicit_edge_blobs():
    # empty dep set, non-ASCII id, empty user bytes
    h = Header(frozenset())
    assert Header.decode(h.encode()) == h
    v = Vertex("注文サービス-ü", 0, 0)
    assert decode_report(encode_report(PersistReport(v, ()))) == PersistReport(v, ())
    assert decode_metadata(encode_metadata(0, -1, [], b"")) == (0, -1, (), b"")
    assert decode_reports(encode_reports([])) == []


def test_canonical_header_bytes():
    """Equal headers encode to equal bytes (deps are sorted canonically) —
    dedup and caching layers may key on the encoding."""
    a = Header.of(Vertex("a", 0, 1), Vertex("b", 0, 2))
    b = Header.of(Vertex("b", 0, 2), Vertex("a", 0, 1))
    assert a.encode() == b.encode()


def test_binary_smaller_than_json():
    deps = [Vertex("order-service", 0, i) for i in range(8)]
    user = bytes(range(64))
    assert len(encode_metadata(1, 9, deps, user)) < len(
        encode_metadata_json(1, 9, deps, user)
    )
