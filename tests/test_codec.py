"""Binary wire codec round-trip tests (DESIGN.md §9).

Hypothesis drives header / metadata / report / decision / boundary blobs
through encode→decode — including non-ASCII so_ids, empty dep sets, and
negative watermarks — and pins the legacy-JSON fallback so blobs persisted
by pre-codec builds stay decodable forever.
"""
from __future__ import annotations

import json

from repro.core.ids import (
    Header,
    PersistReport,
    RollbackDecision,
    Vertex,
    WIRE_MAGIC,
    decode_boundary,
    decode_decision,
    decode_decisions,
    decode_metadata,
    decode_report,
    decode_reports,
    encode_boundary,
    encode_decision,
    encode_decisions,
    encode_metadata,
    encode_metadata_json,
    encode_report,
    encode_reports,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is optional (CI runs a without-matrix leg)
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    # so_ids: printable ASCII and non-ASCII (CJK, umlauts, emoji) — anything
    # a deployment might name a service; empty excluded (not a legal id).
    SO_IDS = st.text(
        alphabet=st.characters(blacklist_categories=("Cs",)), min_size=1, max_size=24
    )
    VERSIONS = st.integers(min_value=-1, max_value=2**40)
    WORLDS = st.integers(min_value=0, max_value=2**20)

    VERTICES = st.builds(Vertex, so_id=SO_IDS, world=WORLDS, version=VERSIONS)
    HEADERS = st.builds(
        lambda vs: Header(frozenset(vs)), st.lists(VERTICES, max_size=8)
    )
    REPORTS = st.builds(
        PersistReport, vertex=VERTICES, deps=st.lists(VERTICES, max_size=8).map(tuple)
    )
    DECISIONS = st.builds(
        RollbackDecision,
        fsn=st.integers(min_value=0, max_value=2**20),
        failed=SO_IDS,
        targets=st.dictionaries(SO_IDS, VERSIONS, max_size=8),
    )

    @settings(max_examples=200, deadline=None)
    @given(h=HEADERS)
    def test_header_round_trip(h):
        raw = h.encode()
        assert raw[0] == WIRE_MAGIC
        assert Header.decode(raw) == h

    @settings(max_examples=200, deadline=None)
    @given(
        world=WORLDS,
        version=VERSIONS,
        deps=st.lists(VERTICES, max_size=8),
        user=st.binary(max_size=64),
    )
    def test_metadata_round_trip(world, version, deps, user):
        raw = encode_metadata(world, version, deps, user=user)
        assert decode_metadata(raw) == (world, version, tuple(deps), user)

    @settings(max_examples=200, deadline=None)
    @given(r=REPORTS)
    def test_report_round_trip(r):
        assert decode_report(encode_report(r)) == r

    @settings(max_examples=100, deadline=None)
    @given(rs=st.lists(REPORTS, max_size=12))
    def test_report_batch_round_trip(rs):
        assert decode_reports(encode_reports(rs)) == rs

    @settings(max_examples=100, deadline=None)
    @given(ds=st.lists(DECISIONS, max_size=8))
    def test_decision_round_trip(ds):
        assert decode_decisions(encode_decisions(ds)) == ds
        for d in ds:
            assert decode_decision(encode_decision(d)) == d

    @settings(max_examples=100, deadline=None)
    @given(b=st.dictionaries(SO_IDS, VERSIONS, max_size=12))
    def test_boundary_round_trip(b):
        assert decode_boundary(encode_boundary(b)) == b

    @settings(max_examples=100, deadline=None)
    @given(
        world=WORLDS,
        version=VERSIONS,
        deps=st.lists(VERTICES, max_size=6),
        user=st.binary(max_size=32),
    )
    def test_metadata_legacy_json_fallback(world, version, deps, user):
        """Blobs persisted by pre-codec builds (JSON, hex-doubled user
        bytes) must decode identically forever — DESIGN.md §9."""
        raw = encode_metadata_json(world, version, deps, user=user)
        assert raw[:1] == b"{"
        assert decode_metadata(raw) == (world, version, tuple(deps), user)

    @settings(max_examples=100, deadline=None)
    @given(h=HEADERS)
    def test_header_legacy_json_fallback(h):
        legacy = json.dumps(sorted(v.to_json() for v in h.deps)).encode()
        assert Header.decode(legacy) == h


def test_seeded_round_trip_sweep():
    """Deterministic PRNG sweep over the same blob space — real coverage on
    the without-hypothesis CI leg and in local quick runs."""
    import random

    rng = random.Random(20260729)
    alphabet = "abzü注文🦜-/  \x00"

    def so_id():
        return "".join(rng.choice(alphabet) for _ in range(rng.randint(1, 12)))

    def vertex():
        return Vertex(so_id(), rng.randint(0, 2**20), rng.randint(-1, 2**40))

    for _ in range(300):
        h = Header(frozenset(vertex() for _ in range(rng.randint(0, 6))))
        assert Header.decode(h.encode()) == h
        r = PersistReport(vertex(), tuple(vertex() for _ in range(rng.randint(0, 6))))
        assert decode_report(encode_report(r)) == r
        rs = [
            PersistReport(vertex(), tuple(vertex() for _ in range(rng.randint(0, 4))))
            for _ in range(rng.randint(0, 8))
        ]
        assert decode_reports(encode_reports(rs)) == rs
        world, version = rng.randint(0, 2**20), rng.randint(-1, 2**40)
        deps = [vertex() for _ in range(rng.randint(0, 6))]
        user = bytes(rng.randint(0, 255) for _ in range(rng.randint(0, 48)))
        assert decode_metadata(encode_metadata(world, version, deps, user)) == (
            world,
            version,
            tuple(deps),
            user,
        )
        assert decode_metadata(encode_metadata_json(world, version, deps, user)) == (
            world,
            version,
            tuple(deps),
            user,
        )
        d = RollbackDecision(
            fsn=rng.randint(0, 2**20),
            failed=so_id(),
            targets={so_id(): rng.randint(-1, 2**30) for _ in range(rng.randint(0, 5))},
        )
        assert decode_decision(encode_decision(d)) == d
        b = {so_id(): rng.randint(-1, 2**30) for _ in range(rng.randint(0, 8))}
        assert decode_boundary(encode_boundary(b)) == b


def test_explicit_edge_blobs():
    # empty dep set, non-ASCII id, empty user bytes
    h = Header(frozenset())
    assert Header.decode(h.encode()) == h
    v = Vertex("注文サービス-ü", 0, 0)
    assert decode_report(encode_report(PersistReport(v, ()))) == PersistReport(v, ())
    assert decode_metadata(encode_metadata(0, -1, [], b"")) == (0, -1, (), b"")
    assert decode_reports(encode_reports([])) == []


def test_canonical_header_bytes():
    """Equal headers encode to equal bytes (deps are sorted canonically) —
    dedup and caching layers may key on the encoding."""
    a = Header.of(Vertex("a", 0, 1), Vertex("b", 0, 2))
    b = Header.of(Vertex("b", 0, 2), Vertex("a", 0, 1))
    assert a.encode() == b.encode()


def test_binary_smaller_than_json():
    deps = [Vertex("order-service", 0, i) for i in range(8)]
    user = bytes(range(64))
    assert len(encode_metadata(1, 9, deps, user)) < len(
        encode_metadata_json(1, 9, deps, user)
    )


# --------------------------------------------------------------------------- #
# truncated-buffer rejection + adversarial inputs (PR-4)                       #
# --------------------------------------------------------------------------- #
def _sample_blobs():
    """(decoder, blob) per kind, with non-ASCII ids, dense and empty dep
    sets, large varints (> 2^32 versions), and raw user bytes."""
    v = Vertex("注文サービス-ü🦜", 3, 2**40 + 17)
    dense = tuple(Vertex(f"s{i}", i, 2**33 + i) for i in range(6))
    return [
        (Header.decode, Header.of(v, *dense).encode()),
        (Header.decode, Header(frozenset()).encode()),
        (decode_metadata, encode_metadata(2**20, 2**40, list(dense), user=bytes(range(48)))),
        (decode_report, encode_report(PersistReport(v, dense, seq=2**34))),
        (
            decode_reports,
            encode_reports([PersistReport(v, (), seq=0), PersistReport(v, dense, seq=1)]),
        ),
        (
            decode_decision,
            encode_decision(
                RollbackDecision(fsn=2**20, failed="注文", targets={"a": -1, "b": 2**40})
            ),
        ),
        (
            decode_decisions,
            encode_decisions(
                [RollbackDecision(fsn=1, failed="x", targets={}) for _ in range(3)]
            ),
        ),
        (decode_boundary, encode_boundary({"注文": -1, "s1": 2**40})),
    ]


def _decoders():
    return [
        Header.decode,
        decode_metadata,
        decode_report,
        decode_reports,
        decode_decision,
        decode_decisions,
        decode_boundary,
    ]


def test_truncated_buffers_rejected_exhaustively():
    """EVERY strict prefix of every blob kind must raise ValueError — never
    silently decode to a shortened string/dep-set/user-bytes payload (the
    pre-PR-4 readers sliced past the end and returned corrupt values)."""
    for decode, raw in _sample_blobs():
        assert decode(raw) is not None  # full blob decodes
        for cut in range(len(raw)):
            try:
                decode(raw[:cut])
            except ValueError:
                continue
            except IndexError as e:  # pragma: no cover - would be a regression
                raise AssertionError(
                    f"truncation at {cut}/{len(raw)} leaked IndexError"
                ) from e
            raise AssertionError(
                f"truncated blob (cut {cut}/{len(raw)}, kind {raw[1]}) "
                "decoded without error"
            )


def test_wrong_kind_and_garbage_rejected():
    import pytest

    blob = encode_boundary({"a": 1})
    for wrong in _decoders():
        if wrong is decode_boundary:
            continue
        with pytest.raises(ValueError):
            wrong(blob)
    with pytest.raises(ValueError):
        decode_report(b"")
    with pytest.raises(ValueError):
        decode_report(bytes([0xD5]))
    with pytest.raises(ValueError):
        # malformed: unterminated varint (all continuation bits)
        decode_boundary(bytes([0xD5, 7]) + b"\xff" * 16)


def _legacy_report_blob(reports, batch: bool) -> bytes:
    """Hand-rolled pre-seq (kind 3/4) report layout: vertex, dep count,
    deps — no seq field. Pins the on-wire bytes an old peer produces."""
    from repro.core.ids import K_REPORT, K_REPORTS, _begin, _finish, _write_vertex, _w_uvarint

    prefix, body, tab = _begin(K_REPORTS if batch else K_REPORT)
    if batch:
        _w_uvarint(body, len(reports))
    for r in reports:
        _write_vertex(body, tab, r.vertex)
        _w_uvarint(body, len(r.deps))
        for d in r.deps:
            _write_vertex(body, tab, d)
    return _finish(prefix, body, tab)


def test_legacy_report_kind_fallback():
    """The seq field took a NEW kind byte (DESIGN.md §9 versioning rule):
    writers emit kind 8/9, but kind-3/4 blobs from pre-seq builds decode
    forever, as seq=-1."""
    from repro.core.ids import K_REPORT2, K_REPORTS2

    v = Vertex("注文-svc", 1, 7)
    deps = (Vertex("b", 0, 3),)
    r = PersistReport(v, deps)  # seq=-1
    assert encode_report(r)[1] == K_REPORT2
    assert encode_reports([r])[1] == K_REPORTS2
    assert decode_report(_legacy_report_blob([r], batch=False)) == r
    assert decode_reports(_legacy_report_blob([r, r], batch=True)) == [r, r]


def test_legacy_report_truncation_rejected():
    v = Vertex("svc", 0, 1)
    raw = _legacy_report_blob([PersistReport(v, (v,))], batch=False)
    import pytest

    for cut in range(len(raw)):
        with pytest.raises(ValueError):
            decode_report(raw[:cut])


def test_report_seq_round_trip_and_json_interop():
    """The PR-4 ``seq`` field survives binary and JSON paths in both
    directions, and legacy JSON without a seq decodes as seq=-1."""
    r = PersistReport(Vertex("ü", 1, 2), (Vertex("b", 0, 1),), seq=7)
    assert decode_report(encode_report(r)) == r
    assert decode_reports(encode_reports([r, r])) == [r, r]
    assert PersistReport.from_json(r.to_json()) == r
    legacy = {"v": ["ü", 1, 2], "deps": [["b", 0, 1]]}  # pre-seq JSON shape
    assert PersistReport.from_json(legacy).seq == -1
    no_seq = PersistReport(Vertex("a", 0, 0), ())
    assert "seq" not in no_seq.to_json()
    assert PersistReport.from_json(no_seq.to_json()) == no_seq


def test_json_interop_both_directions():
    """Legacy-JSON interop is bidirectional for every type with a JSON
    form: obj -> to_json -> from_json -> obj, and json.dumps round-trips
    (wire-safe for the JSONL coordinator logs)."""
    d = RollbackDecision(fsn=9, failed="注文", targets={"a": -1, "b": 2**40})
    assert RollbackDecision.from_json(json.loads(json.dumps(d.to_json()))) == d
    v = Vertex("注文", 1, 2**40)
    assert Vertex.from_json(json.loads(json.dumps(v.to_json()))) == v
    r = PersistReport(v, (v,), seq=3)
    assert PersistReport.from_json(json.loads(json.dumps(r.to_json()))) == r


if HAVE_HYPOTHESIS:

    @settings(max_examples=150, deadline=None)
    @given(r=REPORTS, seq=st.integers(min_value=-1, max_value=2**40), data=st.data())
    def test_truncation_rejection_hypothesis(r, seq, data):
        """Random report blobs (non-ASCII ids, empty/dense dep sets, large
        varints) truncated at a random point must raise ValueError."""
        import pytest

        raw = encode_report(PersistReport(r.vertex, r.deps, seq=seq))
        cut = data.draw(st.integers(min_value=0, max_value=len(raw) - 1))
        with pytest.raises(ValueError):
            decode_report(raw[:cut])
