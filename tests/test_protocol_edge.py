"""Additional protocol edge cases: strict commit-ordering backpressure,
prune-driven memory bounds, multiple overlapping failures, and the
version-relabel equivalence between strict and relabel modes."""
from __future__ import annotations

import time

import pytest

from repro.core import DependencyGraph, LocalCluster
from conftest import CounterSO, make_counter


class TestStrictBackpressure:
    def test_strict_mode_acts_as_straggler_backpressure(self, cluster_factory, tmp_path):
        """A fast producer cannot run arbitrarily far ahead of a slow
        consumer's persistence in strict mode (paper Def 4.1 / §5.3):
        receiving forces the consumer to catch up its local version."""
        c = cluster_factory(
            refresh_interval=None, group_commit_interval=99, strict_commit_ordering=True
        )
        fast = c.add("fast", make_counter(tmp_path, "f"))
        slow = c.add("slow", make_counter(tmp_path, "s"))
        for _ in range(10):
            fast.runtime.maybe_persist(force=True)
        _, h = fast.increment(None)
        assert h.max_version_for() == 11
        slow.increment(h)
        # slow persisted its way up to the sender watermark
        assert slow.runtime.stats()["v_cur"] >= 11
        assert len(slow.runtime.stats()["labels"]) >= 10

    def test_relabel_and_strict_agree_on_values(self, cluster_factory, tmp_path):
        """DESIGN.md §2 equivalence: both modes produce the same application
        state; they differ only in persistence work on the receive path."""
        results = {}
        for mode in (False, True):
            c = cluster_factory(
                f"m{mode}", refresh_interval=None,
                group_commit_interval=99, strict_commit_ordering=mode,
            )
            p = c.add("p", make_counter(tmp_path, f"pp{mode}"))
            q = c.add("q", make_counter(tmp_path, f"qq{mode}"))
            for _ in range(3):
                p.runtime.maybe_persist(force=True)
            _, h = p.increment(None)
            v, _ = q.increment(h, by=5)
            results[mode] = (p.value, v)
        assert results[False] == results[True]


class TestPruning:
    def test_boundary_advance_prunes_graph_and_store(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.004)
        so = c.add("ctr", make_counter(tmp_path, "pr"))
        for i in range(6):
            so.increment(None)
            so.runtime.maybe_persist(force=True)
            time.sleep(0.01)
        # settle: reports flushed, boundary advanced, prune delivered
        for _ in range(5):
            c.refresh_all()
            time.sleep(0.01)
        st = so.runtime.stats()
        assert st["boundary"]["ctr"] >= 4
        # local label list is pruned to the boundary floor
        assert len(st["labels"]) <= 3
        # coordinator graph stays bounded
        assert c.coordinator.stats()["graph_vertices"] <= 4


class TestMultiFailure:
    def test_overlapping_failures_converge(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        sos = {n: c.add(n, make_counter(tmp_path, f"mf{n}")) for n in "abc"}
        # a -> b -> c speculative chain
        _, ha = sos["a"].increment(None)
        _, hb = sos["b"].increment(ha)
        sos["c"].increment(hb)
        # two failures back-to-back, before anyone refreshes
        c.kill("a")
        c.kill("b")
        for _ in range(3):
            c.refresh_all()
        a, b, cc = (c.get(n) for n in "abc")
        assert a.runtime.world == b.runtime.world == cc.runtime.world == 2
        # everything speculative rolled back everywhere
        assert a.value == 0 and b.value == 0 and cc.value == 0

    def test_failure_of_every_member_then_recovery(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        p = c.add("p", make_counter(tmp_path, "ap"))
        q = c.add("q", make_counter(tmp_path, "aq"))
        _, h = p.increment(None)
        q.increment(h)
        assert q.StartAction(None) and q.wait_durable(timeout=5.0)
        q.EndAction()
        c.kill("p")
        c.kill("q")
        p2, q2 = c.get("p"), c.get("q")
        # durable prefix survived both failures
        assert p2.value == 1 and q2.value == 1
        # and the system keeps working once everyone reaches the same epoch
        # (p restarted at fsn=1; q's failure minted fsn=2 — a header from
        # world 1 at a world-2 receiver is DISCARDED per Def 4.3, so the
        # sender must refresh first)
        c.refresh_all()
        assert p2.runtime.world == q2.runtime.world == 2
        _, h = p2.increment(None)
        assert q2.increment(h) is not None
