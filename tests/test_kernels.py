"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py
oracles, plus a hypothesis property test for delta-encode round-trips and a
cross-check of the model's chunked SSD against the sequential oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked


# --------------------------------------------------------------------------- #
# flash attention                                                              #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("s,d,bq,bk", [
    (128, 64, 64, 64),
    (256, 64, 128, 64),
    (256, 128, 128, 128),
    (64, 32, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_core(s, d, bq, bk, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (2, s, d), dtype)
    k = jax.random.normal(k2, (2, s, d), dtype)
    v = jax.random.normal(k3, (2, s, d), dtype)
    from repro.kernels.flash_attention import flash_attention as fa_core

    out = fa_core(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("nq,nkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_gqa_wrapper(nq, nkv):
    b, s, hd = 2, 128, 64
    keys = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(keys[0], (b, s, nq, hd), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, nkv, hd), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, nkv, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    kr = jnp.repeat(k, nq // nkv, axis=2)
    vr = jnp.repeat(v, nq // nkv, axis=2)
    want = jnp.stack([
        ref.flash_attention_ref(
            q[:, :, h].reshape(b, s, hd), kr[:, :, h], vr[:, :, h], causal=True
        ) for h in range(nq)
    ], axis=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# SSD                                                                          #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("s,h,p,n,g,chunk", [
    (64, 2, 16, 16, 1, 16),
    (128, 4, 32, 32, 2, 32),
    (64, 2, 64, 128, 1, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_sequential_oracle(s, h, p, n, g, chunk, dtype):
    keys = jax.random.split(jax.random.key(2), 4)
    b = 2
    x = jax.random.normal(keys[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h), jnp.float32)) * 0.1
    A = -jnp.exp(jax.random.normal(keys[2], (h,), jnp.float32) * 0.3)
    Bm = jax.random.normal(keys[3], (b, s, g, n), dtype) * 0.5
    Cm = jax.random.normal(keys[0], (b, s, g, n), dtype) * 0.5
    out = ops.ssd(x, dt.astype(dtype), A, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt.astype(dtype), A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_model_chunked_ssd_matches_oracle():
    """The model's XLA chunked path must equal the sequential recurrence."""
    b, s, h, p, n, g = 2, 64, 4, 16, 16, 1
    keys = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    Bm = jax.random.normal(keys[3], (b, s, g, n)) * 0.5
    Cm = jax.random.normal(keys[0], (b, s, g, n)) * 0.5
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    want = ref.ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# delta encode                                                                 #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("nb,blk", [(4, 256), (16, 1024), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_delta_encode_matches_ref_and_roundtrips(nb, blk, dtype):
    k1, k2 = jax.random.split(jax.random.key(4))
    prev = jax.random.normal(k1, (nb, blk), dtype)
    new = prev + jax.random.normal(k2, (nb, blk), dtype) * 0.01
    codes, scales = ops.delta_encode(new, prev, interpret=True)
    codes_r, scales_r = ref.delta_encode_ref(new, prev)
    # codes may differ by 1 at exact rounding ties (bf16 inputs); scales match
    diff = np.abs(np.asarray(codes, np.int32) - np.asarray(codes_r, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 0.02
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_r), rtol=1e-6)
    dec = ops.delta_decode(codes, scales, prev, dtype=jnp.float32, interpret=True)
    err = np.max(np.abs(np.asarray(dec) - np.asarray(new, np.float32)))
    # quantization error bound: scale/2 per element
    assert err <= float(np.max(np.asarray(scales))) * 0.51 + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 4),
    blk=st.sampled_from([128, 256]),
    mag=st.floats(1e-6, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_delta_roundtrip_error_bound_property(nb, blk, mag, seed):
    """Property: decode(encode(new, prev), prev) is within one quant step."""
    k1, k2 = jax.random.split(jax.random.key(seed))
    prev = jax.random.normal(k1, (nb, blk), jnp.float32)
    new = prev + jax.random.normal(k2, (nb, blk), jnp.float32) * mag
    codes, scales = ref.delta_encode_ref(new, prev)
    dec = ref.delta_decode_ref(codes, scales, prev, dtype=jnp.float32)
    err = np.abs(np.asarray(dec) - np.asarray(new))
    bound = np.asarray(scales)[:, None] * 0.51 + 1e-6
    assert (err <= bound).all()
