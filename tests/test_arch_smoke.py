"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + a few decode steps on CPU; asserts shapes + no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import (
    abstract_params,
    cache_descs,
    decode_step,
    forward,
    init_params,
    lm_loss,
    param_descs,
)
from repro.models.params import PDesc, is_desc

B, S = 2, 16


def _extras(cfg, batch=B):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((batch, cfg.source_len, cfg.d_model), jnp.float32) * 0.01}
    if cfg.family == "vlm":
        return {"image_embeds": jnp.ones((batch, cfg.num_image_tokens, cfg.d_model), jnp.float32) * 0.01}
    return {}


@pytest.fixture(scope="module", params=ARCHITECTURES)
def arch(request):
    return request.param


def test_param_descs_build_and_count(arch):
    cfg = get_config(arch, smoke=True)
    descs = param_descs(cfg)
    leaves = jax.tree_util.tree_leaves(descs, is_leaf=is_desc)
    assert all(isinstance(l, PDesc) for l in leaves)
    abstract = abstract_params(descs)
    assert jax.tree_util.tree_structure(abstract) == jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda d: 0, descs, is_leaf=is_desc)
    )


def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    descs = param_descs(cfg)
    params = init_params(descs, jax.random.key(0), dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab_size)
    extras = _extras(cfg)

    def loss_fn(p):
        logits, _, aux = forward(cfg, p, tokens[:, :-1], extras=extras)
        return lm_loss(cfg, logits, tokens[:, 1:], aux), logits

    (loss, logits), grads = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # the loss is a real LM loss: near log(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)
    gnorm = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.square(l.astype(jnp.float32)))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0.0


def test_decode_steps(arch):
    cfg = get_config(arch, smoke=True)
    descs = param_descs(cfg)
    params = init_params(descs, jax.random.key(0), dtype=jnp.float32)
    cdescs = cache_descs(cfg, batch=B, max_len=32)
    cache = jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape, jnp.float32), cdescs, is_leaf=is_desc
    )
    extras = _extras(cfg)
    if cfg.family == "encdec":
        # prime encoder output once (prefill-equivalent for the stub frontend)
        logits, cache2, _ = forward(
            cfg, params, jnp.zeros((B, 1), jnp.int32), extras=extras,
            cache=cache, cache_index=jnp.asarray(0, jnp.int32),
        )
        cache = cache2

    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i, extras=extras))
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
        assert logits.shape == (B, 1, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)


def test_full_configs_have_exact_dims():
    """Spot-check the exact published dimensions of the full configs."""
    import math

    full = {a: get_config(a) for a in ARCHITECTURES}
    assert full["yi_6b"].d_model == 4096 and full["yi_6b"].num_kv_heads == 4
    assert full["gemma_2b"].num_kv_heads == 1 and full["gemma_2b"].head_dim == 256
    assert full["glm4_9b"].num_layers == 40 and full["glm4_9b"].vocab_size == 151552
    assert full["gemma3_4b"].global_period == 6 and full["gemma3_4b"].sliding_window == 1024
    assert full["zamba2_1p2b"].ssm.d_state == 64
    assert full["granite_moe_3b_a800m"].moe.num_experts == 40
    ds = full["deepseek_v2_lite_16b"]
    assert ds.mla.kv_lora_rank == 512 and ds.moe.top_k == 6 and ds.moe.num_shared == 2
    assert full["mamba2_370m"].ssm.d_state == 128 and full["mamba2_370m"].num_layers == 48
    v = full["llama_3p2_vision_90b"]
    assert v.num_layers == 100 and v.d_model == 8192 and v.cross_attn_period == 5
    s = full["seamless_m4t_large_v2"]
    assert s.encoder_layers == 24 and s.vocab_size == 256206
    # every padded vocab is a multiple of 2048
    for cfg in full.values():
        assert cfg.vocab_padded % 2048 == 0 and cfg.vocab_padded >= cfg.vocab_size
