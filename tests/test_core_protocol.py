"""Protocol-level tests for the libDSE core (paper §3–§4).

Covers: dependency-graph fixpoints, commit ordering (both relabel and
paper-literal strict modes), speculative rollback + message discard,
skip-rollback mitigation (§5.3), sthreads + barriers, the recovery
partition rule across failure epochs, and coordinator failure/recovery.
"""
from __future__ import annotations

import time

import pytest

from repro.core import (
    DelayMessage,
    DependencyGraph,
    Header,
    RollbackDecision,
    RolledBackError,
    Vertex,
)

from conftest import CounterSO, make_counter, wait_committed


# --------------------------------------------------------------------------- #
# dependency graph fixpoints                                                   #
# --------------------------------------------------------------------------- #
class TestGraph:
    def test_boundary_simple_chain(self):
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        g.report_persistent("B", 0, [])
        g.report_persistent("A", 1, [])
        g.report_persistent("B", 1, [("A", 1)])
        assert g.recoverable_boundary() == {"A": 1, "B": 1}

    def test_boundary_dangling_dep_cuts_consumer(self):
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        g.report_persistent("B", 0, [])
        # B@1 depends on A@1 which is NOT persisted yet => B@1 outside boundary
        g.report_persistent("B", 1, [("A", 1)])
        b = g.recoverable_boundary()
        assert b["B"] == 0 and b["A"] == 0
        # once A@1 becomes durable the boundary catches up
        g.report_persistent("A", 1, [])
        assert g.recoverable_boundary() == {"A": 1, "B": 1}

    def test_boundary_transitive_cut(self):
        g = DependencyGraph()
        for so in "ABC":
            g.report_persistent(so, 0, [])
        g.report_persistent("B", 2, [("A", 2)])  # A@2 missing
        g.report_persistent("C", 3, [("B", 2)])
        b = g.recoverable_boundary()
        # watermark cuts exclude B@2 and C@3; snapped to loadable labels = v0
        assert b["B"] < 2 and b["C"] < 3
        assert g.snap_to_labels(b) == {"A": 0, "B": 0, "C": 0}

    def test_boundary_cycle_is_fine(self):
        # Vertices capture many transitions => cycles possible (paper §4.2).
        g = DependencyGraph()
        g.report_persistent("A", 1, [("B", 1)])
        g.report_persistent("B", 1, [("A", 1)])
        assert g.recoverable_boundary() == {"A": 1, "B": 1}

    def test_rollback_targets(self):
        g = DependencyGraph()
        for so in "ABC":
            g.report_persistent(so, 0, [])
        g.report_persistent("A", 1, [])
        g.report_persistent("A", 2, [])
        g.report_persistent("B", 2, [("A", 2)])
        g.report_persistent("C", 2, [("B", 2)])
        # A fails having lost version 2 (survived only up to 1):
        t = g.rollback_targets("A", 1)
        assert t["A"] == 1
        assert t["B"] == 0  # B@2 depended on lost A@2
        assert t["C"] == 0  # transitively
        # commit-ordering => watermark sets are closures: no domino below 0
        assert all(v >= 0 for v in t.values())

    def test_decision_invalidates(self):
        d = RollbackDecision(fsn=1, failed="A", targets={"A": 1, "B": 0})
        assert d.invalidates(Vertex("A", 0, 2))
        assert not d.invalidates(Vertex("A", 0, 1))
        assert not d.invalidates(Vertex("A", 1, 5))  # created post-recovery
        assert d.invalidates(Vertex("B", 0, 1))
        assert not d.invalidates(Vertex("C", 0, 9))  # not a participant


# --------------------------------------------------------------------------- #
# single StateObject basics                                                    #
# --------------------------------------------------------------------------- #
class TestSingleSO:
    def test_connect_persists_v0_and_actions_run(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        so = c.add("ctr", make_counter(tmp_path, "ctr"))
        assert so.runtime.stats()["committed"] == 0
        v, h = so.increment(None)
        assert v == 1 and h.deps
        (dep,) = h.deps
        assert dep.so_id == "ctr" and dep.world == 0

    def test_barrier_waits_for_durability(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        so = c.add("ctr", make_counter(tmp_path, "ctr"))
        assert so.StartAction(None)
        so.value += 10
        t = so.Detach()
        t.Barrier(timeout=5.0)
        # after the barrier our own vertex is inside the boundary
        st = so.runtime.stats()
        assert st["boundary"]["ctr"] >= 1
        assert so.Merge(t)
        so.EndAction()

    def test_restart_resumes_from_persisted_prefix(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        so = c.add("ctr", make_counter(tmp_path, "ctr"))
        assert so.StartAction(None)
        so.value = 42
        t = so.Detach()
        t.Barrier(timeout=5.0)
        assert so.Merge(t)
        so.EndAction()
        so2 = c.kill("ctr")
        assert so2 is not so
        assert so2.value == 42  # durable prefix survived the crash
        assert so2.runtime.world == 1


# --------------------------------------------------------------------------- #
# commit ordering (Def 4.1)                                                    #
# --------------------------------------------------------------------------- #
class TestCommitOrdering:
    def test_relabel_mode_bumps_receiver_version(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "p"))
        q = c.add("q", make_counter(tmp_path, "q"))
        for _ in range(4):
            p.runtime.maybe_persist(force=True)  # p's v_cur -> 5
        _, h = p.increment(None)
        assert h.max_version_for() == 5
        _, hq = q.increment(h)
        # receiver label >= sender label (no blocking in relabel mode)
        assert hq.max_version_for() >= 5
        assert q.runtime.stats()["v_cur"] >= 5

    def test_strict_mode_blocks_via_persistence(self, cluster_factory, tmp_path):
        c = cluster_factory(
            refresh_interval=None, group_commit_interval=99, strict_commit_ordering=True
        )
        p = c.add("p", make_counter(tmp_path, "sp"))
        q = c.add("q", make_counter(tmp_path, "sq"))
        for _ in range(4):
            p.runtime.maybe_persist(force=True)
        _, h = p.increment(None)
        before = len(q.runtime.stats()["labels"])
        _, hq = q.increment(h)
        after = len(q.runtime.stats()["labels"])
        # paper-literal behaviour: q persisted repeatedly to catch up
        assert after > before
        assert hq.max_version_for() >= 5


# --------------------------------------------------------------------------- #
# group commit (maybe_persist due/dirty/force semantics)                       #
# --------------------------------------------------------------------------- #
class TestGroupCommit:
    def test_dirty_but_not_due_skips(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=999)
        so = c.add("g", make_counter(tmp_path, "g"))
        so.increment(None)
        assert so.runtime.maybe_persist() is None  # dirty, interval not elapsed

    def test_due_but_clean_skips(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=0.0)
        so = c.add("g", make_counter(tmp_path, "g"))
        # v0 was persisted at Connect and nothing has dirtied state since:
        # an always-due interval alone must not trigger an empty persist.
        assert so.runtime.maybe_persist() is None

    def test_due_and_dirty_persists(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=0.0)
        so = c.add("g", make_counter(tmp_path, "g"))
        so.increment(None)
        label = so.runtime.maybe_persist()
        assert label is not None and label >= 1
        assert so.runtime.maybe_persist() is None  # clean again afterwards

    def test_force_persists_even_clean_and_not_due(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=999)
        so = c.add("g", make_counter(tmp_path, "g"))
        assert so.runtime.maybe_persist(force=True) is not None


# --------------------------------------------------------------------------- #
# rollback + message discard                                                   #
# --------------------------------------------------------------------------- #
class TestRollback:
    def test_speculative_consumer_rolls_back(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "rp"))
        q = c.add("q", make_counter(tmp_path, "rq"))
        _, h = p.increment(None)          # speculative: never persisted
        res = q.increment(h, by=100)      # q consumed speculative state
        assert res is not None and q.value == 100
        c.kill("p")                        # p loses its in-memory increment
        c.refresh_all()                    # deliver the decision to q
        assert q.value == 0                # q rolled back to v0
        assert q.runtime.world == 1
        # stale header from the pre-failure epoch must be discarded
        assert q.increment(h) is None

    def test_skip_rollback_for_unaffected_peer(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "kp"))
        q = c.add("q", make_counter(tmp_path, "kq"))
        b = c.add("b", make_counter(tmp_path, "kb"))
        _, h = p.increment(None)
        q.increment(h, by=100)
        b.increment(None, by=7)           # b never saw p's speculative state
        c.kill("p")
        c.refresh_all()
        assert q.value == 0               # affected: rolled back
        assert b.value == 7               # §5.3 mitigation: skip, keep in-mem
        assert b.runtime.world == 1       # but the epoch still advances

    def test_durable_state_survives_peer_failure(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        p = c.add("p", make_counter(tmp_path, "dp"))
        q = c.add("q", make_counter(tmp_path, "dq"))
        _, h = p.increment(None)
        assert q.StartAction(h)
        q.value += 100
        t = q.Detach()
        t.Barrier(timeout=5.0)            # now both p@1 and q@1 are durable
        assert q.Merge(t)
        q.EndAction()
        c.kill("p")
        c.refresh_all()
        assert q.value == 100             # inside the boundary: survives

    def test_decision_targeting_unreported_v0_clamps_to_floor(
        self, cluster_factory, tmp_path
    ):
        """A decision computed before our synchronous v0 report arrived can
        assign target -1; the runtime must clamp to its durable floor (the
        Connect-time snapshot) instead of attempting Restore(-1)."""
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        z = c.add("z", make_counter(tmp_path, "z"))
        z.increment(None)
        z.runtime._apply_decision(
            RollbackDecision(fsn=1, failed="other", targets={"z": -1})
        )
        assert z.runtime.world == 1
        assert z.value == 0  # restored to v0, not v-1

    def test_rolled_back_sthread_raises(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "tp"))
        q = c.add("q", make_counter(tmp_path, "tq"))
        _, h = p.increment(None)
        assert q.StartAction(h)
        t = q.Detach()                    # sthread derives from speculative q
        c.kill("p")
        c.refresh_all()
        with pytest.raises(RolledBackError):
            t.Send()
        assert not q.Merge(t)


# --------------------------------------------------------------------------- #
# recovery partition rule (Def 4.3)                                            #
# --------------------------------------------------------------------------- #
class TestEpochPartition:
    def test_old_world_discarded_future_world_delayed(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "ep"))
        q = c.add("q", make_counter(tmp_path, "eq"))
        _, h_old = p.increment(None)      # world-0 header
        q2 = c.kill("q")                  # fsn=1; q2 is post-recovery
        # p has not yet heard of the failure: p stays in world 0
        assert p.runtime.world == 0
        # post-recovery q2 receives a pre-recovery message: m < x => discard
        assert q2.increment(h_old) is None
        # pre-recovery p receives a post-recovery message: m > x => delay
        _, h_new = q2.increment(None)
        with pytest.raises(DelayMessage):
            p.increment(h_new)
        p.Refresh()                       # applies the decision, world -> 1
        assert p.runtime.world == 1
        assert p.increment(h_new) is not None

    def test_recovery_sequencing_applies_decisions_in_order(
        self, cluster_factory, tmp_path
    ):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "qp"))
        a = c.add("a", make_counter(tmp_path, "qa"))
        b = c.add("b", make_counter(tmp_path, "qb"))
        c.kill("a")
        c.kill("b")
        assert p.runtime.world == 0
        p.Refresh()                       # both decisions arrive together
        assert p.runtime.world == 2       # applied 1 then 2 (Def 4.2)


# --------------------------------------------------------------------------- #
# coordinator failure + recovery (paper §4.3)                                  #
# --------------------------------------------------------------------------- #
class TestCoordinatorRecovery:
    def test_boundary_unavailable_until_fragments_resent(
        self, cluster_factory, tmp_path
    ):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "cp"))
        q = c.add("q", make_counter(tmp_path, "cq"))
        _, h = p.increment(None)
        q.increment(h)
        assert wait_committed(p, p.runtime.maybe_persist(force=True))
        assert wait_committed(q, q.runtime.maybe_persist(force=True))
        c.refresh_all()
        old_boundary = c.coordinator.current_boundary()
        assert old_boundary is not None

        c.restart_coordinator()
        # view incomplete: no boundary answers yet
        assert c.coordinator.current_boundary() is None
        assert c.coordinator.stats()["awaiting"] == ["p", "q"]
        c.refresh_all()                    # participants resend fragments
        new_boundary = c.coordinator.current_boundary()
        assert new_boundary is not None
        # view is at least as fresh as before the coordinator failure
        for so, wm in old_boundary.items():
            assert new_boundary[so] >= wm

    def test_failure_decisions_survive_coordinator_restart(
        self, cluster_factory, tmp_path
    ):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "fp"))
        q = c.add("q", make_counter(tmp_path, "fq"))
        _, h = p.increment(None)
        q.increment(h, by=100)
        c.kill("p")                        # decision fsn=1 durably logged
        c.restart_coordinator()
        c.refresh_all()                    # resend fragments; deliver decision
        c.refresh_all()
        assert q.value == 0                # rollback still applied
        assert q.runtime.world == 1

    def test_so_failure_during_coordinator_recovery_waits(
        self, cluster_factory, tmp_path
    ):
        c = cluster_factory(refresh_interval=0.002, group_commit_interval=0.005)
        p = c.add("p", make_counter(tmp_path, "wp"))
        q = c.add("q", make_counter(tmp_path, "wq"))
        p.increment(None)
        c.restart_coordinator()
        # kill + restart q while the coordinator is still collecting
        # fragments: connect must block until p has resent, then decide.
        q2 = c.kill("q")
        assert q2.runtime.world == 1


# --------------------------------------------------------------------------- #
# O(delta) hot path: seq-gated polls + compacted decision index (DESIGN §9)    #
# --------------------------------------------------------------------------- #
class TestPollDelta:
    def _coord(self, tmp_path):
        from repro.core import Coordinator

        return Coordinator(tmp_path / "coord.jsonl")

    def test_poll_gates_boundary_on_seq(self, tmp_path):
        from repro.core import PersistReport

        coord = self._coord(tmp_path)
        coord.connect("A", [])
        coord.report("A", [PersistReport(Vertex("A", 0, 1), ())])
        first = coord.poll("A", 0)
        assert first.boundary == {"A": 1}
        # nothing moved: quoting the seq back elides the boundary entirely
        again = coord.poll("A", 0, first.boundary_seq)
        assert again.boundary is None
        assert again.boundary_seq == first.boundary_seq
        # progress bumps the seq and ships the new boundary
        coord.report("A", [PersistReport(Vertex("A", 0, 2), ())])
        moved = coord.poll("A", 0, first.boundary_seq)
        assert moved.boundary == {"A": 2}
        assert moved.boundary_seq > first.boundary_seq
        coord.close()

    def test_poll_decisions_are_a_delta(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "dp"))
        c.add("q", make_counter(tmp_path, "dq"))
        p.increment(None)
        c.kill("p")  # decision fsn=1
        c.kill("p")  # decision fsn=2
        assert [d.fsn for d in c.coordinator.poll("q", 0).decisions] == [1, 2]
        assert [d.fsn for d in c.coordinator.poll("q", 1).decisions] == [2]
        assert c.coordinator.poll("q", 2).decisions == []

    def test_decision_index_matches_linear_scan(self):
        from repro.core import DecisionIndex
        from repro.core.ids import vertex_rolled_back

        decisions = [
            RollbackDecision(fsn=1, failed="A", targets={"A": 1, "B": 0}),
            RollbackDecision(fsn=3, failed="B", targets={"B": 4, "C": 2}),
            RollbackDecision(fsn=5, failed="A", targets={"A": 7, "B": 2}),
        ]
        idx = DecisionIndex(decisions)
        for so in "ABCD":
            for world in range(7):
                for version in range(-1, 9):
                    v = Vertex(so, world, version)
                    assert idx.invalidates(v) == vertex_rolled_back(v, decisions), v

    def test_runtime_forgets_seq_on_coordinator_restart(
        self, cluster_factory, tmp_path
    ):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        p = c.add("p", make_counter(tmp_path, "sp"))
        p.increment(None)
        assert wait_committed(p, p.runtime.maybe_persist(force=True))
        c.refresh_all()
        assert p.runtime.boundary.get("p", -1) >= 1
        c.restart_coordinator()
        c.refresh_all()  # resend_fragments resets the known seq...
        c.refresh_all()  # ...so the next poll ships the full boundary again
        assert p.runtime.boundary.get("p", -1) >= 1
