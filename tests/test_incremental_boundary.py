"""Incremental boundary maintenance == from-scratch fixpoint oracle.

The coordinator hot path maintains the recoverable boundary incrementally
(waiters index + pending frontier + same-version cycle rescue, DESIGN.md
§9); ``DependencyGraph.recoverable_boundary()`` keeps the original global
fixpoint as the slow-path oracle. These tests drive random report /
rollback interleavings through both and require exact equivalence, plus a
set of hand-built adversarial shapes (cycles, blocked chains, label gaps).
"""
from __future__ import annotations

from repro.core.graph import DependencyGraph


def check(g: DependencyGraph) -> None:
    _, inc = g.incremental_boundary()
    assert inc == g.recoverable_boundary()


class TestIncrementalBoundaryShapes:
    def test_chain_in_order(self):
        g = DependencyGraph()
        for v in range(5):
            g.report_persistent("A", v, [])
            g.report_persistent("B", v, [("A", v)])
            check(g)
        assert g.incremental_boundary()[1] == {"A": 4, "B": 4}

    def test_chain_out_of_order(self):
        """B's reports arrive before the A vertices they depend on: B stays
        cut until A's reports land, then the waiters index cascades."""
        g = DependencyGraph()
        for v in range(4):
            g.report_persistent("B", v, [("A", v)])
        check(g)
        assert g.incremental_boundary()[1]["B"] == -1
        for v in range(4):
            g.report_persistent("A", v, [])
            check(g)
        assert g.incremental_boundary()[1] == {"A": 3, "B": 3}

    def test_same_version_cycle(self):
        """A_1 <-> B_1 mutual dependency (legal: the commit ordering rule
        only forces dep.version <= vertex.version) — one-at-a-time
        admission deadlocks; the frontier rescue must admit the pair."""
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        g.report_persistent("B", 0, [])
        g.report_persistent("A", 1, [("B", 1)])
        check(g)  # blocked: B_1 not persisted yet
        g.report_persistent("B", 1, [("A", 1)])
        check(g)
        assert g.incremental_boundary()[1] == {"A": 1, "B": 1}

    def test_three_way_cycle_with_tail(self):
        g = DependencyGraph()
        for so in "ABC":
            g.report_persistent(so, 0, [])
        g.report_persistent("A", 2, [("B", 2)])
        check(g)
        g.report_persistent("B", 2, [("C", 2)])
        check(g)
        g.report_persistent("C", 2, [("A", 2)])
        check(g)
        assert g.incremental_boundary()[1] == {"A": 2, "B": 2, "C": 2}
        # D depends on the cycle after it resolved
        g.report_persistent("D", 3, [("A", 2)])
        check(g)
        assert g.incremental_boundary()[1]["D"] == 3

    def test_label_gap_cut_semantics(self):
        """Blocked label 5 over persisted labels [0, 5]: the oracle cuts to
        4 (a non-label watermark); incremental must match exactly."""
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        g.report_persistent("A", 5, [("B", 5)])
        check(g)
        assert g.incremental_boundary()[1]["A"] == 4

    def test_truncate_rebuilds(self):
        g = DependencyGraph()
        for v in range(4):
            g.report_persistent("A", v, [])
            g.report_persistent("B", v, [("A", v)])
        g.truncate("A", 1)
        check(g)
        # B's vertices above A's surviving prefix are cut by the fixpoint
        assert g.incremental_boundary()[1] == {"A": 1, "B": 1}
        # and incremental maintenance resumes after the rebuild
        g.report_persistent("A", 2, [])
        g.report_persistent("B", 4, [("A", 2)])
        check(g)

    def test_prune_above_watermark_invalidates(self):
        """A sharded caller may prune to an externally-computed boundary
        above this graph's incremental watermark, removing a blocked label
        the incremental state still tracks — must rebuild, not wedge
        (code-review regression)."""
        g = DependencyGraph()
        g.report_persistent("a", 4, [])
        g.report_persistent("a", 3, [("c", 1)])  # blocked: a stuck at 2
        check(g)
        assert g.incremental_boundary()[1]["a"] == 2
        g.prune("a", 8)  # floor moves to label 4, dropping blocked label 3
        check(g)
        assert g.incremental_boundary()[1]["a"] == 4

    def test_prune_preserves_boundary(self):
        g = DependencyGraph()
        for v in range(6):
            g.report_persistent("A", v, [])
            g.report_persistent("B", v, [("A", v)])
        _, before = g.incremental_boundary()
        for so, b in before.items():
            g.prune(so, b)
        check(g)
        assert g.incremental_boundary()[1] == before

    def test_boundary_version_monotone_and_quiescent(self):
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        v1 = g.boundary_version()
        g.report_persistent("B", 1, [("A", 2)])  # blocked: no advance for B
        v2 = g.boundary_version()
        assert v2 >= v1
        # queries without mutation never bump the version (poll gating)
        assert g.boundary_version() == v2
        assert g.boundary_version() == v2

    def test_unknown_dep_so(self):
        g = DependencyGraph()
        g.report_persistent("A", 1, [("ghost", 0)])
        check(g)
        assert g.incremental_boundary()[1]["A"] == 0

    def test_remove_member_rebuilds(self):
        g = DependencyGraph()
        g.report_persistent("A", 0, [])
        g.report_persistent("B", 1, [("A", 0)])
        g.remove_member("A")
        check(g)

    def test_blocked_vertex_arrives_below_watermark(self):
        """Out-of-order delivery: A@2 (clean) admitted first, then A@1
        arrives with an unsatisfied dep. The admitted prefix is no longer a
        closure — the incremental state must fall back to the oracle's cut
        instead of staying over-advanced (code-review regression)."""
        g = DependencyGraph()
        g.report_persistent("A", 2, [])
        assert g.incremental_boundary()[1] == {"A": 2}
        g.report_persistent("A", 1, [("B", 5)])
        check(g)
        assert g.incremental_boundary()[1]["A"] == 0

    def test_changed_deps_on_blocked_label_reregisters_waiters(self):
        """Re-reporting the blocked label with a DIFFERENT dep list must
        re-register waiters on the new deps — otherwise the later advance of
        the new dep's owner never re-attempts and the boundary wedges
        (code-review regression; protocol traffic never mutates a persisted
        vertex, but the public API allows it)."""
        g = DependencyGraph()
        g.report_persistent("s2", 0, [("s3", 0)])
        g.report_persistent("s2", 0, [("s1", 0)])  # dep list replaced
        g.report_persistent("s1", 2, [])
        check(g)
        assert g.incremental_boundary()[1]["s2"] == 0

    def test_satisfied_vertex_below_watermark_keeps_boundary(self):
        g = DependencyGraph()
        g.report_persistent("B", 3, [])
        g.report_persistent("A", 2, [])
        g.report_persistent("A", 1, [("B", 1)])  # satisfied: no invalidation
        check(g)
        assert g.incremental_boundary()[1]["A"] == 2


N_SOS = 4


def _random_ops(rng, n_ops):
    """Random report/rollback interleavings honouring the commit ordering
    rule (dep.version <= vertex.version) that the equivalence argument —
    and the protocol — rely on. Versions may skip labels (relabeling gaps)
    and reports may arrive in any cross-SO order."""
    ops = []
    next_version = [0] * N_SOS
    for _ in range(n_ops):
        so = rng.randrange(N_SOS)
        if next_version[so] > 0 and rng.random() < 0.15:
            ops.append(("truncate", so, rng.randint(-1, next_version[so] - 1)))
            continue
        version = next_version[so] + rng.randint(0, 2)
        next_version[so] = version + 1
        deps = []
        for dep_so in rng.sample(range(N_SOS), rng.randint(0, 3)):
            if dep_so == so:
                continue
            deps.append((dep_so, rng.randint(0, version)))
        ops.append(("report", so, version, deps))
    return ops


def _apply(g, op):
    if op[0] == "report":
        _, so, version, deps = op
        g.report_persistent(f"so{so}", version, [(f"so{d}", dv) for d, dv in deps])
    else:
        _, so, keep = op
        g.truncate(f"so{so}", keep)


def test_incremental_equals_oracle_seeded_sweep():
    """Deterministic PRNG sweep: 150 random interleavings, equivalence
    checked after EVERY op (runs on the without-hypothesis CI leg too)."""
    import random

    for seed in range(150):
        rng = random.Random(seed)
        g = DependencyGraph()
        for op in _random_ops(rng, rng.randint(1, 40)):
            _apply(g, op)
            _, inc = g.incremental_boundary()
            oracle = g.recoverable_boundary()
            assert inc == oracle, (
                f"seed={seed} divergence after {op}: "
                f"incremental={inc} oracle={oracle}"
            )
            if rng.random() < 0.3:
                for so_id, b in inc.items():
                    g.prune(so_id, b)
                assert g.incremental_boundary()[1] == g.recoverable_boundary()


def test_incremental_equals_oracle_reordered_delivery():
    """Reports generated in protocol order but DELIVERED in a windowed
    shuffle — the fabric reorders, retries, and interleaves concurrent
    flushes, so vertices routinely land below an already-advanced
    watermark."""
    import random

    for seed in range(120):
        rng = random.Random(10_000 + seed)
        reports = [op for op in _random_ops(rng, 30) if op[0] == "report"]
        # windowed shuffle: each report may be delayed by up to 6 slots
        order = sorted(range(len(reports)), key=lambda i: i + rng.random() * 6)
        g = DependencyGraph()
        for i in order:
            _apply(g, reports[i])
            _, inc = g.incremental_boundary()
            oracle = g.recoverable_boundary()
            assert inc == oracle, (
                f"seed={seed} divergence after {reports[i]}: "
                f"incremental={inc} oracle={oracle}"
            )


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is optional (CI runs a without-matrix leg)
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def op_sequences(draw):
        n_ops = draw(st.integers(min_value=1, max_value=40))
        ops = []
        next_version = [0] * N_SOS
        for _ in range(n_ops):
            so = draw(st.integers(min_value=0, max_value=N_SOS - 1))
            if next_version[so] > 0 and draw(st.booleans()) and draw(st.booleans()):
                # occasional rollback: truncate to a random surviving prefix
                keep = draw(st.integers(min_value=-1, max_value=next_version[so] - 1))
                ops.append(("truncate", so, keep))
                continue
            version = next_version[so] + draw(st.integers(min_value=0, max_value=2))
            next_version[so] = version + 1
            deps = []
            for dep_so in draw(
                st.lists(
                    st.integers(min_value=0, max_value=N_SOS - 1),
                    max_size=3,
                    unique=True,
                )
            ):
                if dep_so == so:
                    continue
                deps.append(
                    (dep_so, draw(st.integers(min_value=0, max_value=version)))
                )
            ops.append(("report", so, version, deps))
        return ops

    @settings(max_examples=120, deadline=None)
    @given(ops=op_sequences())
    def test_incremental_equals_oracle_under_random_interleavings(ops):
        g = DependencyGraph()
        for op in ops:
            _apply(g, op)
            _, inc = g.incremental_boundary()
            assert inc == g.recoverable_boundary(), (
                f"divergence after {op}: incremental={inc} "
                f"oracle={g.recoverable_boundary()}"
            )

    @settings(max_examples=80, deadline=None)
    @given(ops=op_sequences(), data=st.data())
    def test_incremental_equals_oracle_reordered_delivery_hypothesis(ops, data):
        reports = [op for op in ops if op[0] == "report"]
        jitter = [
            data.draw(st.floats(min_value=0, max_value=6)) for _ in reports
        ]
        order = sorted(range(len(reports)), key=lambda i: i + jitter[i])
        g = DependencyGraph()
        for i in order:
            _apply(g, reports[i])
            _, inc = g.incremental_boundary()
            assert inc == g.recoverable_boundary()

    @settings(max_examples=60, deadline=None)
    @given(ops=op_sequences(), data=st.data())
    def test_incremental_equals_oracle_with_interleaved_pruning(ops, data):
        """Pruning (what the coordinator does after every boundary advance)
        must never perturb the equivalence."""
        g = DependencyGraph()
        for op in ops:
            _apply(g, op)
            _, inc = g.incremental_boundary()
            assert inc == g.recoverable_boundary()
            if data.draw(st.booleans()):
                for so_id, b in inc.items():
                    g.prune(so_id, b)
                assert g.incremental_boundary()[1] == g.recoverable_boundary()
