"""Property-based protocol tests: under ARBITRARY interleavings of
speculative operations, persists, and crash-restarts, the system always
recovers to a causally-consistent prefix:

  invariant 1 (prefix): a consumer never holds state derived from a
      producer state that no longer exists (consumer_count <= producer_count);
  invariant 2 (monotone boundary): the recoverable boundary never regresses;
  invariant 3 (no zombie epochs): all live SOs converge to the same world
      after refresh.

Plus the DecisionIndex differential property (mirroring the
incremental-boundary equivalence harness in test_incremental_boundary.py):
under random decision/probe/rebuild interleavings, the compacted per-SO
suffix-minima index classifies every vertex exactly like the linear scan
over the full decision list. The seeded sweep runs on the
without-hypothesis CI leg too; hypothesis widens the same space.
"""
from __future__ import annotations

import random

import pytest

from repro.core.ids import DecisionIndex, RollbackDecision, Vertex, vertex_rolled_back

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is optional (CI runs a without-matrix leg)
    HAVE_HYPOTHESIS = False

from repro.core import DelayMessage, LocalCluster
from repro.services.counter import CounterStateObject


# --------------------------------------------------------------------------- #
# DecisionIndex ≡ linear-scan oracle                                           #
# --------------------------------------------------------------------------- #
_SOS = [f"so{i}" for i in range(4)] + ["注文-svc"]


def _random_decision(rng: random.Random, fsn: int) -> RollbackDecision:
    targets = {
        so: rng.randint(-1, 12)
        for so in rng.sample(_SOS, rng.randint(0, len(_SOS)))
    }
    failed = rng.choice(_SOS)
    return RollbackDecision(fsn=fsn, failed=failed, targets=targets)


def _probe_vertices(rng: random.Random, n: int):
    return [
        Vertex(rng.choice(_SOS), rng.randint(0, 8), rng.randint(-1, 14))
        for _ in range(n)
    ]


def test_decision_index_equals_linear_scan_seeded_sweep():
    """Deterministic PRNG sweep: random report(probe)/rollback(add)/
    prune(rebuild-from-scratch) interleavings, classification equivalence
    checked against the ``vertex_rolled_back`` linear scan after every op
    — including fsn gaps, empty target maps, and -1 watermarks."""
    for seed in range(200):
        rng = random.Random(seed)
        decisions = []
        idx = DecisionIndex()
        fsn = 0
        for _ in range(rng.randint(1, 25)):
            roll = rng.random()
            if roll < 0.45 or not decisions:
                fsn += rng.randint(1, 3)  # fsn gaps: shard-allocated ranges
                d = _random_decision(rng, fsn)
                decisions.append(d)
                idx.add(d)
            elif roll < 0.75:
                pass  # probe-only round (report classification)
            else:
                # "prune"/compaction round: a fresh index over the same
                # decision list (what connect() builds) must agree with the
                # incrementally-grown one
                idx = DecisionIndex(decisions)
            for v in _probe_vertices(rng, 8):
                got = idx.invalidates(v)
                want = vertex_rolled_back(v, decisions)
                assert got == want, (
                    f"seed={seed} divergence on {v!r}: index={got} scan={want} "
                    f"decisions={[d.to_json() for d in decisions]}"
                )
            probes = _probe_vertices(rng, 4)
            assert idx.any_invalid(probes) == any(
                vertex_rolled_back(v, decisions) for v in probes
            )


if HAVE_HYPOTHESIS:
    _H_SO = st.sampled_from([f"so{i}" for i in range(4)] + ["注文-svc"])
    _H_DECISIONS = st.lists(
        st.builds(
            RollbackDecision,
            fsn=st.integers(min_value=1, max_value=40),
            failed=_H_SO,
            targets=st.dictionaries(_H_SO, st.integers(min_value=-1, max_value=12), max_size=5),
        ),
        max_size=12,
    )
    _H_VERTICES = st.lists(
        st.builds(
            Vertex,
            so_id=_H_SO,
            world=st.integers(min_value=0, max_value=40),
            version=st.integers(min_value=-1, max_value=14),
        ),
        min_size=1,
        max_size=12,
    )

    @settings(max_examples=200, deadline=None)
    @given(decisions=_H_DECISIONS, probes=_H_VERTICES)
    def test_decision_index_equals_linear_scan_hypothesis(decisions, probes):
        idx = DecisionIndex(decisions)
        grown = DecisionIndex()
        for d in decisions:
            grown.add(d)
        for v in probes:
            want = vertex_rolled_back(v, decisions)
            assert idx.invalidates(v) == want
            assert grown.invalidates(v) == want
        assert idx.any_invalid(probes) == any(
            vertex_rolled_back(v, decisions) for v in probes
        )


def _run_prefix_consistency(tmp_path_factory, ops):
    root = tmp_path_factory.mktemp("prop")
    with LocalCluster(root, refresh_interval=None, group_commit_interval=99) as cluster:
        cluster.add("p", lambda: CounterStateObject(root / "p"))
        cluster.add("c", lambda: CounterStateObject(root / "c"))
        boundary_high = {}

        for op in ops:
            p, c = cluster.get("p"), cluster.get("c")
            if op[0] == "inc":
                try:
                    out = p.increment(None)
                    if out is None:
                        continue
                    _, hdr = out
                    c.increment(hdr)  # mirror: c depends on p's state
                except DelayMessage:
                    cluster.refresh_all()
            elif op[0] == "persist":
                so = cluster.get(op[1])
                try:
                    so.runtime.maybe_persist(force=True)
                except Exception:
                    pass
            else:  # kill + auto-restart
                cluster.kill(op[1])
                cluster.refresh_all()

            # invariant 2: the boundary never regresses
            b = cluster.coordinator.current_boundary()
            if b:
                for so_id, wm in b.items():
                    assert wm >= boundary_high.get(so_id, -1), (so_id, wm, boundary_high)
                    boundary_high[so_id] = wm

        # settle: apply outstanding decisions everywhere
        for _ in range(3):
            cluster.refresh_all()
        p, c = cluster.get("p"), cluster.get("c")
        # invariant 1: consumer state is a prefix of producer state
        assert c.value <= p.value, (c.value, p.value)
        # invariant 3: same failure epoch everywhere
        assert p.runtime.world == c.runtime.world


if HAVE_HYPOTHESIS:
    # op alphabet: ("inc", ) producer increment + mirror to consumer;
    #              ("persist", who) force persist; ("kill", who) crash-restart
    OPS = st.lists(
        st.one_of(
            st.just(("inc",)),
            st.tuples(st.just("persist"), st.sampled_from(["p", "c"])),
            st.tuples(st.just("kill"), st.sampled_from(["p", "c"])),
        ),
        min_size=1,
        max_size=24,
    )

    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[
            HealthCheck.function_scoped_fixture,
            HealthCheck.data_too_large,
        ],
    )
    @given(ops=OPS)
    def test_prefix_consistency_under_arbitrary_failures(tmp_path_factory, ops):
        _run_prefix_consistency(tmp_path_factory, ops)


def test_prefix_consistency_seeded_smoke(tmp_path_factory):
    """One deterministic interleaving on the without-hypothesis leg, so the
    cluster-level property has coverage in every CI matrix cell."""
    rng = random.Random(20260730)
    ops = []
    for _ in range(18):
        r = rng.random()
        if r < 0.6:
            ops.append(("inc",))
        elif r < 0.8:
            ops.append(("persist", rng.choice(["p", "c"])))
        else:
            ops.append(("kill", rng.choice(["p", "c"])))
    _run_prefix_consistency(tmp_path_factory, ops)
