"""Property-based protocol tests (hypothesis): under ARBITRARY interleavings
of speculative operations, persists, and crash-restarts, the system always
recovers to a causally-consistent prefix:

  invariant 1 (prefix): a consumer never holds state derived from a
      producer state that no longer exists (consumer_count <= producer_count);
  invariant 2 (monotone boundary): the recoverable boundary never regresses;
  invariant 3 (no zombie epochs): all live SOs converge to the same world
      after refresh.
"""
from __future__ import annotations

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import DelayMessage, LocalCluster
from repro.services.counter import CounterStateObject


# op alphabet: ("inc", ) producer increment + mirror to consumer;
#              ("persist", who) force persist; ("kill", who) crash-restart
OPS = st.lists(
    st.one_of(
        st.just(("inc",)),
        st.tuples(st.just("persist"), st.sampled_from(["p", "c"])),
        st.tuples(st.just("kill"), st.sampled_from(["p", "c"])),
    ),
    min_size=1,
    max_size=24,
)


@settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.function_scoped_fixture, HealthCheck.data_too_large],
)
@given(ops=OPS)
def test_prefix_consistency_under_arbitrary_failures(tmp_path_factory, ops):
    root = tmp_path_factory.mktemp("prop")
    with LocalCluster(root, refresh_interval=None, group_commit_interval=99) as cluster:
        cluster.add("p", lambda: CounterStateObject(root / "p"))
        cluster.add("c", lambda: CounterStateObject(root / "c"))
        boundary_high = {}

        for op in ops:
            p, c = cluster.get("p"), cluster.get("c")
            if op[0] == "inc":
                try:
                    out = p.increment(None)
                    if out is None:
                        continue
                    _, hdr = out
                    c.increment(hdr)  # mirror: c depends on p's state
                except DelayMessage:
                    cluster.refresh_all()
            elif op[0] == "persist":
                so = cluster.get(op[1])
                try:
                    so.runtime.maybe_persist(force=True)
                except Exception:
                    pass
            else:  # kill + auto-restart
                cluster.kill(op[1])
                cluster.refresh_all()

            # invariant 2: the boundary never regresses
            b = cluster.coordinator.current_boundary()
            if b:
                for so_id, wm in b.items():
                    assert wm >= boundary_high.get(so_id, -1), (so_id, wm, boundary_high)
                    boundary_high[so_id] = wm

        # settle: apply outstanding decisions everywhere
        for _ in range(3):
            cluster.refresh_all()
        p, c = cluster.get("p"), cluster.get("c")
        # invariant 1: consumer state is a prefix of producer state
        assert c.value <= p.value, (c.value, p.value)
        # invariant 3: same failure epoch everywhere
        assert p.runtime.world == c.runtime.world
