"""Behavioural tests for the §5.2 speculative services and §6.1 apps."""
from __future__ import annotations

import time

import pytest

from repro.core import Header

from conftest import wait_committed
from repro.services import (
    EventBroker,
    SpeculativeKVStore,
    SpeculativeLog,
    TwoPCClient,
    TwoPCCoordinator,
    TwoPCParticipant,
    WorkflowEngine,
)


# --------------------------------------------------------------------------- #
# speculative log                                                              #
# --------------------------------------------------------------------------- #
class TestSpeculativeLog:
    def test_append_scan_and_durability(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        log = c.add("log", lambda: SpeculativeLog(tmp_path / "log"))
        for i in range(5):
            off, h = log.append(f"e{i}".encode())
            assert off == i
        assert log.StartAction(None)
        assert log.wait_durable(timeout=5.0)
        log.EndAction()
        log2 = c.kill("log")
        entries, _ = log2.scan(0)
        assert [d for _, d in entries] == [f"e{i}".encode() for i in range(5)]

    def test_speculative_entries_lost_on_crash(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        log = c.add("log", lambda: SpeculativeLog(tmp_path / "slog"))
        log.append(b"volatile")
        log2 = c.kill("log")
        entries, _ = log2.scan(0)
        assert entries == []  # speculative appends rolled back

    def test_consumed_entries_skip_storage(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        log = c.add("log", lambda: SpeculativeLog(tmp_path / "plog"))
        for i in range(10):
            log.append(f"evt{i}".encode())
        # a consumer acked the first 8 before any flush happened
        log.truncate_consumed(8)
        assert wait_committed(log, log.runtime.maybe_persist(force=True))
        assert log.core.entries_skipped == 8
        # survivors are still durable and holes read as pruned
        log.core.drop_memory()
        log.core.restore(1)
        assert [d for _, d in log.core.scan(0)] == [b"evt8", b"evt9"]

    def test_restore_fast_path_truncates_in_memory(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        log = c.add("log", lambda: SpeculativeLog(tmp_path / "flog"))
        log.append(b"a")
        log.runtime.maybe_persist(force=True)
        time.sleep(0.03)
        log.append(b"b")  # speculative
        meta = log.core.restore(1)  # roll back in memory
        assert [d for _, d in log.core.scan(0)] == [b"a"]
        assert isinstance(meta, bytes)


# --------------------------------------------------------------------------- #
# KV store                                                                     #
# --------------------------------------------------------------------------- #
class TestKVStore:
    def test_put_get_and_reserve(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        kv = c.add("kv", lambda: SpeculativeKVStore(tmp_path / "kv"))
        kv.stock("hotel", 2)
        ok, _ = kv.try_reserve("hotel", "wf1")
        assert ok
        ok, _ = kv.try_reserve("hotel", "wf2")
        assert ok
        ok, _ = kv.try_reserve("hotel", "wf3")
        assert not ok  # sold out
        kv.release("hotel", "wf1")
        ok, _ = kv.try_reserve("hotel", "wf3")
        assert ok

    def test_speculative_reservation_rolls_back(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        kv = c.add("kv", lambda: SpeculativeKVStore(tmp_path / "rkv"))
        kv.stock("car", 1)
        assert kv.StartAction(None)
        assert kv.wait_durable(timeout=5.0)  # stock survives
        kv.EndAction()
        kv.try_reserve("car", "wfX")  # speculative
        kv2 = c.kill("kv")
        c.refresh_all()
        v, _ = kv2.get("inv:car")
        assert v == "1"  # reservation was rolled back with the crash


# --------------------------------------------------------------------------- #
# workflow engine (TravelReservations, paper Fig. 9)                           #
# --------------------------------------------------------------------------- #
def _mk_travel(cluster, tmp_path, runtime="dse", n_services=3):
    names = [f"svc{i}" for i in range(n_services)]
    kvs = []
    for n in names:
        kv = cluster.add(
            n, (lambda n=n: SpeculativeKVStore(tmp_path / f"kv_{n}")), runtime=runtime
        )
        kv.stock("item", 100)
        kvs.append(kv)
    wf = cluster.add(
        "wf", lambda: WorkflowEngine(tmp_path / "wf"), runtime=runtime
    )
    return wf, kvs


def _steps(kvs, wf_id):
    return [
        (lambda hdr, kv=kv: kv.try_reserve("item", wf_id, hdr)) for kv in kvs
    ]


class TestWorkflow:
    def test_travel_reservation_completes(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        wf, kvs = _mk_travel(c, tmp_path)
        out = wf.run_workflow("wf1", _steps(kvs, "wf1"))
        assert out is not None
        results, _ = out
        assert results == [True, True, True]
        assert wf.workflow_state("wf1")["status"] == "done"

    def test_baseline_mode_also_completes(self, cluster_factory, tmp_path):
        """The durable-execution baseline (synchronous persistence at every
        transition, DurableRuntime) runs the identical orchestration code."""
        c = cluster_factory(group_commit_interval=0.005)
        wf, kvs = _mk_travel(c, tmp_path, runtime="durable")
        out = wf.run_workflow("wf1", _steps(kvs, "wf1"))
        assert out is not None
        results, _ = out
        assert results == [True, True, True]
        # durable semantics: the acked workflow is already non-speculative
        assert wf.runtime.kind == "durable"
        assert wf.runtime.stats()["committed"] >= 0

    def test_crash_rolls_back_and_resumes_consistently(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        wf, kvs = _mk_travel(c, tmp_path)
        # make stock durable first so rollback targets stock=100
        for i, kv in enumerate(kvs):
            assert kv.StartAction(None)
            assert kv.wait_durable(timeout=5.0)
            kv.EndAction()

        # run the workflow WITHOUT the external barrier so it stays speculative
        out = wf.run_workflow("wf2", _steps(kvs, "wf2"), external=False)
        assert out is not None
        # now crash the middle service before anything else persists
        kv1 = c.kill("svc1")
        c.refresh_all()
        # the workflow engine consumed svc1's speculative state => rolled back
        st = wf.workflow_state("wf2")
        assert st is None or st["step"] < 3 or wf.runtime.world == 1
        # all reservations from the dead run must be gone everywhere
        for kv in [kvs[0], kv1, kvs[2]]:
            live = c.get(["svc0", "svc1", "svc2"][[kvs[0], kv1, kvs[2]].index(kv)])
            v, _ = live.get("inv:item")
            assert v == "100"
        # driver resumes: full re-execution yields a consistent final state
        # (external=False: no barrier — this cluster has no refresher thread)
        out = wf.run_workflow(
            "wf2", _steps([c.get(n) for n in ("svc0", "svc1", "svc2")], "wf2"),
            external=False,
        )
        assert out is not None
        for n in ("svc0", "svc1", "svc2"):
            v, _ = c.get(n).get("inv:item")
            assert v == "99"


# --------------------------------------------------------------------------- #
# event broker                                                                 #
# --------------------------------------------------------------------------- #
class TestBroker:
    def test_produce_consume_ack(self, cluster_factory, tmp_path):
        c = cluster_factory(group_commit_interval=0.005)
        br = c.add("br", lambda: EventBroker(tmp_path / "br", topics=["t0"]))
        offs, h = br.produce("t0", [b"a", b"b", b"c"])
        assert offs == [0, 1, 2]
        evts, h2 = br.consume("g", "t0", header=h)
        assert [d for _, d in evts] == [b"a", b"b", b"c"]
        br.ack("g", "t0", upto=2, header=h2)
        evts, _ = br.consume("g", "t0")
        assert evts == []  # offset advanced

    def test_acked_events_skip_storage(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        br = c.add("br", lambda: EventBroker(tmp_path / "br2", topics=["t0"]))
        _, h = br.produce("t0", [f"e{i}".encode() for i in range(20)])
        evts, h2 = br.consume("g", "t0", max_n=20, header=h)
        br.ack("g", "t0", upto=19, header=h2)
        assert wait_committed(br, br.runtime.maybe_persist(force=True))
        assert br.entries_skipped() == 20  # never reached storage (Fig. 10)

    def test_exactly_once_across_rollback(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        br = c.add("br", lambda: EventBroker(tmp_path / "br3", topics=["t0"]))
        _, h = br.produce("t0", [b"x"])
        # consumer processes speculatively but broker crashes before persist
        evts, h2 = br.consume("g", "t0", header=h)
        assert len(evts) == 1
        br2 = c.kill("br")
        c.refresh_all()
        # event is gone (its production was speculative) — and so is the
        # consumer offset: a re-produce is consumed exactly once.
        _, h = br2.produce("t0", [b"x"])
        evts, h2 = br2.consume("g", "t0", header=h)
        assert [d for _, d in evts] == [b"x"]
        br2.ack("g", "t0", 0, header=h2)
        evts, _ = br2.consume("g", "t0")
        assert evts == []


# --------------------------------------------------------------------------- #
# two-phase commit (paper Fig. 11)                                             #
# --------------------------------------------------------------------------- #
class TestTwoPC:
    @pytest.mark.parametrize("speculative", [True, False])
    def test_commit_succeeds(self, cluster_factory, tmp_path, speculative):
        c = cluster_factory(group_commit_interval=0.005)
        parts = [
            c.add(
                f"p{i}",
                (lambda i=i: TwoPCParticipant(tmp_path / f"p{i}", speculative=speculative)),
            )
            for i in range(4)
        ]
        coord = c.add(
            "coord", lambda: TwoPCCoordinator(tmp_path / "coord", speculative=speculative)
        )
        client = TwoPCClient(coord, parts)
        assert client.run("txn1") is True

    def test_lost_start_record_aborts(self, cluster_factory, tmp_path):
        c = cluster_factory(refresh_interval=None, group_commit_interval=99)
        parts = [
            c.add(f"p{i}", (lambda i=i: TwoPCParticipant(tmp_path / f"ap{i}")))
            for i in range(2)
        ]
        coord = c.add("coord", lambda: TwoPCCoordinator(tmp_path / "acoord"))
        # client writes start records (speculative), then p0 crashes
        for p in parts:
            p.txn_start("txnA")
        c.kill("p0")
        c.refresh_all()
        parts = [c.get("p0"), c.get("p1")]
        # prepare: p0 lost the start record => votes no => abort
        out0 = parts[0].prepare("txnA")
        assert out0 is not None and out0[0] is False
