"""Shared fixtures: a minimal CounterStateObject (the paper's running
example, Fig. 3/4) used across protocol tests, and cluster factories.

NOTE: XLA_FLAGS / device-count manipulation is intentionally absent here —
smoke tests and benches must see the 1 real CPU device; only
``repro.launch.dryrun`` installs the 512-device placeholder flag.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Optional, Tuple

import pytest

from repro.core.clock import Clock, REAL_CLOCK
from repro.services.counter import CounterStateObject as CounterSO


@pytest.fixture
def cluster_factory(tmp_path):
    """Yields a factory building LocalClusters rooted under tmp_path."""
    from repro.core import LocalCluster

    made = []

    def make(name: str = "c0", **kw) -> LocalCluster:
        c = LocalCluster(tmp_path / name, **kw)
        made.append(c)
        return c

    yield make
    for c in made:
        c.shutdown()


def make_counter(tmp_path: Path, name: str, io_ms: float = 0.0):
    def factory() -> CounterSO:
        return CounterSO(tmp_path / f"so_{name}", io_ms=io_ms)

    return factory


def wait_committed(
    so, label: Optional[int], timeout: float = 5.0, clock: Clock = REAL_CLOCK
) -> bool:
    """Deadline-poll until the async Persist IO for ``label`` has committed
    (fixed sleeps race the IO thread on a loaded machine). Pass a SimClock to
    poll in virtual time under deterministic simulation."""
    if label is None:
        return True
    deadline = clock.now() + timeout
    while clock.now() < deadline:
        if so.runtime.stats()["committed"] >= label:
            return True
        clock.sleep(0.002)
    return False


def settle(
    predicate,
    cluster=None,
    timeout: float = 10.0,
    interval: float = 0.01,
    clock: Clock = REAL_CLOCK,
) -> bool:
    """Deadline-poll ``predicate``, optionally driving ``cluster`` refresh
    rounds each iteration. Clock-injected: under the real clock this is the
    usual anti-flake poll loop; under a SimClock the waits are virtual and
    the poll runs deterministically (``SimCluster.settle`` is its in-tree
    twin for scenario code)."""
    deadline = clock.now() + timeout
    while clock.now() < deadline:
        if cluster is not None:
            cluster.refresh_all()
        if predicate():
            return True
        clock.sleep(interval)
    return predicate()
