"""Shared fixtures: a minimal CounterStateObject (the paper's running
example, Fig. 3/4) used across protocol tests, and cluster factories.

NOTE: XLA_FLAGS / device-count manipulation is intentionally absent here —
smoke tests and benches must see the 1 real CPU device; only
``repro.launch.dryrun`` installs the 512-device placeholder flag.
"""
from __future__ import annotations

import threading
from pathlib import Path
from typing import Optional, Tuple

import pytest

from repro.services.counter import CounterStateObject as CounterSO


@pytest.fixture
def cluster_factory(tmp_path):
    """Yields a factory building LocalClusters rooted under tmp_path."""
    from repro.core import LocalCluster

    made = []

    def make(name: str = "c0", **kw) -> LocalCluster:
        c = LocalCluster(tmp_path / name, **kw)
        made.append(c)
        return c

    yield make
    for c in made:
        c.shutdown()


def make_counter(tmp_path: Path, name: str, io_ms: float = 0.0):
    def factory() -> CounterSO:
        return CounterSO(tmp_path / f"so_{name}", io_ms=io_ms)

    return factory


def wait_committed(so, label: Optional[int], timeout: float = 5.0) -> bool:
    """Deadline-poll until the async Persist IO for ``label`` has committed
    (fixed sleeps race the IO thread on a loaded machine)."""
    import time

    if label is None:
        return True
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if so.runtime.stats()["committed"] >= label:
            return True
        time.sleep(0.002)
    return False
