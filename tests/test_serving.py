"""Speculative serving loop: derived-state (KV cache) recovery. A crashed
session restores its durable token prefix and REBUILDS the cache by
replay; continued greedy decoding is deterministic, so the final durable
stream equals the failure-free stream."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import init_params, param_descs
from repro.train.serve import run_speculative_serving

CFG = get_config("gemma_2b", smoke=True)


@pytest.fixture(scope="module")
def params():
    return init_params(param_descs(CFG), jax.random.key(0), jnp.float32)


def test_serving_generates_and_exports_durable(tmp_path, params):
    res = run_speculative_serving(tmp_path / "s", CFG, params, n_tokens=8)
    assert res.tokens_generated == 8
    assert res.durable_tokens[: res.tokens_generated]  # barrier-gated export


def test_serving_failure_equals_failure_free(tmp_path, params):
    base = run_speculative_serving(tmp_path / "b", CFG, params, n_tokens=10)
    inj = run_speculative_serving(
        tmp_path / "i", CFG, params, n_tokens=10, kill_at=5
    )
    assert inj.rollbacks == 1
    # derived-state recovery: same deterministic token stream
    assert inj.durable_tokens == base.durable_tokens
