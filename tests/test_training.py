"""DSE-resilient training loop tests: the paper's core claim transplanted
to training — speculative execution past checkpoints with rollback recovery
is EQUIVALENT to failure-free execution (bit-identical parameters), while
external observers never see rolled-back state."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import run_resilient_training

CFG = get_config("gemma_2b", smoke=True)
STEPS = 8


def test_loop_runs_and_losses_finite(tmp_path):
    res = run_resilient_training(tmp_path / "a", CFG, steps=4)
    assert res.final_step == 4
    assert len(res.metrics) == 4
    assert all(np.isfinite(l) for _, l in res.metrics)


def test_failure_run_equals_failure_free_run(tmp_path):
    base = run_resilient_training(tmp_path / "base", CFG, steps=STEPS)
    injected = run_resilient_training(
        tmp_path / "inj", CFG, steps=STEPS, kill_trainer_at=4
    )
    assert injected.rollbacks >= 1
    # THE durable-execution equivalence: identical final parameters
    assert injected.params_digest == base.params_digest
    assert injected.final_step == base.final_step == STEPS


def test_external_metrics_see_each_step_exactly_once(tmp_path):
    res = run_resilient_training(
        tmp_path / "m", CFG, steps=STEPS, kill_trainer_at=5
    )
    ext_steps = [s for s, _ in res.external_metrics]
    # failure transparency: no gaps, no duplicates, despite the rollback
    assert sorted(ext_steps) == list(range(STEPS))
    # and the speculative re-execution produced identical losses
    by_step = {}
    for s, l in res.metrics:
        by_step.setdefault(s, set()).add(round(l, 5))
    assert all(len(v) == 1 for v in by_step.values())


def test_data_pipeline_failure_recovers(tmp_path):
    base = run_resilient_training(tmp_path / "b2", CFG, steps=STEPS)
    injected = run_resilient_training(
        tmp_path / "d", CFG, steps=STEPS, kill_data_at=3
    )
    assert injected.params_digest == base.params_digest


def test_delta_codec_preserves_state(tmp_path):
    base = run_resilient_training(tmp_path / "b3", CFG, steps=STEPS)
    delta = run_resilient_training(
        tmp_path / "dc", CFG, steps=STEPS, kill_trainer_at=4, use_delta_codec=True
    )
    # int8 delta checkpoints restore to the same prefix the full snapshots
    # would; replayed steps give identical digests because restore happens
    # from a BASE version here (base_every=4) — and run must complete.
    assert delta.final_step == STEPS
    assert len(delta.external_metrics) == STEPS


def test_gradient_compression_error_feedback():
    from repro.optim import compress_gradients_int8, decompress_gradients_int8

    key = jax.random.key(0)
    grads = {"a": jax.random.normal(key, (64, 64)), "b": jax.random.normal(key, (8,))}
    ef = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    acc_true = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    acc_q = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    for i in range(20):
        codes, scales, ef = compress_gradients_int8(grads, ef)
        deq = decompress_gradients_int8(codes, scales)
        acc_true = jax.tree_util.tree_map(lambda a, g: a + g, acc_true, grads)
        acc_q = jax.tree_util.tree_map(lambda a, g: a + g, acc_q, deq)
    # error feedback keeps the accumulated quantized stream unbiased: the
    # residual is bounded by one quantization step, NOT O(n_steps)
    for k in grads:
        err = np.max(np.abs(np.asarray(acc_true[k]) - np.asarray(acc_q[k])))
        scale = float(np.max(np.abs(np.asarray(grads[k])))) / 127.0
        assert err <= 2.0 * scale + 1e-6, (k, err, scale)
