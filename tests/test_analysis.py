"""Unit tests for the analysis layer: HLO collective parsing, roofline
terms, and the analytic HBM estimator; plus hypothesis property tests for
spec resolution."""
from __future__ import annotations

import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec

from repro.analysis.hlo import collective_wire_bytes, parse_collectives
from repro.analysis.roofline import active_param_count, model_flops, roofline_terms
from repro.configs import ARCHITECTURES, get_config
from repro.models import shape_by_name
from repro.models.params import PDesc, resolve_spec


HLO = """
HloModule test
%fused = f32[128,256]{1,0} fusion(%a), kind=kLoop
%ar = bf16[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
%ag = f32[64,512]{1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={1}
%rs = bf16[32]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
%a2a = bf16[8,8]{1,0} all-to-all(%w), replica_groups={{0,1,2,3,4,5,6,7}}
%cp = f32[16]{0} collective-permute(%v), source_target_pairs={{0,1}}
%ard = bf16[4]{0} all-reduce-done(%ar2)
"""


class TestHLOParsing:
    def test_parse_finds_all_collectives(self):
        ops = parse_collectives(HLO)
        kinds = [k for k, _, _ in ops]
        assert kinds.count("all-reduce") == 1  # -done skipped
        assert "all-gather" in kinds and "reduce-scatter" in kinds
        assert "all-to-all" in kinds and "collective-permute" in kinds

    def test_shape_bytes_and_group_sizes(self):
        ops = {k: (b, n) for k, b, n in parse_collectives(HLO)}
        assert ops["all-reduce"] == (1024 * 2, 4)
        assert ops["all-gather"] == (64 * 512 * 4, 16)  # [16,16] groups of 16
        assert ops["reduce-scatter"] == (32 * 2, 2)

    def test_wire_byte_formulas(self):
        w = collective_wire_bytes(HLO)
        assert w["all-reduce"] == pytest.approx(2 * 2048 * 3 / 4)
        assert w["all-gather"] == pytest.approx(64 * 512 * 4 * 15 / 16)
        assert w["reduce-scatter"] == pytest.approx(64 * 1)
        assert w["total"] == pytest.approx(
            w["all-reduce"] + w["all-gather"] + w["reduce-scatter"]
            + w["all-to-all"] + w["collective-permute"]
        )


class TestRoofline:
    def test_moe_active_params_smaller_than_total(self):
        cfg = get_config("deepseek_v2_lite_16b")
        assert active_param_count(cfg) < cfg.param_count()

    def test_model_flops_train_is_6nd(self):
        cfg = get_config("yi_6b")
        shape = shape_by_name("train_4k")
        n = active_param_count(cfg)
        assert model_flops(cfg, shape) == pytest.approx(6 * n * 256 * 4096)

    def test_terms_and_dominance(self):
        cfg = get_config("yi_6b")
        shape = shape_by_name("train_4k")
        cost = {"flops": 1e14, "bytes accessed": 1e12}
        coll = {"total": 1e10}
        t = roofline_terms(cost, coll, cfg, shape, chips=256)
        assert t["compute_s"] == pytest.approx(1e14 / 197e12)
        assert t["memory_s"] == pytest.approx(1e12 / 819e9)
        assert t["collective_s"] == pytest.approx(1e10 / 50e9)
        assert t["dominant"] == "memory"
        assert 0 < t["roofline_fraction"] <= 1.0

    def test_param_count_matches_descriptors(self):
        """Analytic param_count vs the descriptor tree (ground truth)."""
        from repro.models import param_count as desc_count, param_descs

        for arch in ARCHITECTURES:
            cfg = get_config(arch)
            analytic = cfg.param_count()
            actual = desc_count(param_descs(cfg))
            assert abs(analytic - actual) / actual < 0.05, (
                arch, analytic, actual
            )


class TestSpecResolution:
    def test_divisibility_fallback(self):
        sizes = {"data": 16, "model": 16}
        rules = {"kv_heads": ("model",), "seq": ("model",), "batch": ("data",)}
        # kv=4 does not divide 16 -> seq takes the model axis
        d = PDesc((128, 32768, 4, 128), ("batch", "seq", "kv_heads", None))
        spec = resolve_spec(d, rules, sizes)
        assert spec == PartitionSpec("data", "model")
        # kv=32 divides -> kv wins over seq (priority)
        d2 = PDesc((128, 32768, 32, 128), ("batch", "seq", "kv_heads", None))
        assert resolve_spec(d2, rules, sizes) == PartitionSpec("data", None, "model")

    @settings(max_examples=50, deadline=None)
    @given(
        dim=st.sampled_from([1, 2, 3, 4, 8, 16, 40, 64, 100, 256]),
        model=st.sampled_from([1, 2, 4, 8, 16]),
    )
    def test_resolution_never_produces_nondividing_spec(self, dim, model):
        sizes = {"model": model, "data": 4}
        rules = {"x": ("model",)}
        d = PDesc((dim,), ("x",))
        spec = resolve_spec(d, rules, sizes)
        if spec and spec[0] is not None:
            assert dim % model == 0
