"""Property-based simulation sweep (hypothesis): random op/fault
interleavings against SpeculativeKVStore under deterministic simulation must
stay linearizable.

Every hypothesis example is one seed; the seed derives the client op
scripts, a benign fault schedule (loss / duplication / delay / partitions /
shard restarts — nothing that loses application state), and every
scheduling decision. The recorded history is checked with the Wing–Gong
linearizability checker. 50 examples, derandomized so CI is reproducible; a
failing seed should be pinned in ``tests/scenarios/regression_seeds.json``.
"""
from __future__ import annotations

import random
from functools import partial
from pathlib import Path

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.sim import FaultPlan, KVModel, RecordingClient, SimCluster, check_linearizable  # noqa: E402


def _kv_lin_scenario(seed: int, root: Path) -> None:
    """A compact kv workload (2 clients, 6 ops each) under a seed-derived
    benign fault schedule; raises if the recorded history is not
    linearizable. Smaller than explore.kv_scenario so 50 hypothesis examples
    stay inside the tier-1 time budget."""
    from repro.services.kv_store import SpeculativeKVStore

    horizon = 0.4
    plan = FaultPlan.random(
        seed, so_ids=["kv"], horizon=horizon, n_shards=2, allow_crash=False, max_events=3
    )
    rng = random.Random(seed ^ 0x11EA12)
    keys = ["x", "y"]
    scripts = [
        [
            (rng.choice(["put", "get", "get", "delete"]), rng.choice(keys),
             f"v{rng.randrange(20)}", rng.uniform(0.0, 0.03))
            for _ in range(6)
        ]
        for _ in range(2)
    ]
    sim = SimCluster(
        root,
        seed=seed,
        n_shards=2,
        refresh_interval=0.005,
        group_commit_interval=0.01,
        call_timeout=20.0,
    )

    def scenario(sim: SimCluster):
        sim.add("kv", lambda: SpeculativeKVStore(sim.root / "so_kv"))

        def client(i: int) -> None:
            cli = RecordingClient(sim, "kv", f"cli{i}")
            for method, key, value, pause in scripts[i]:
                if method == "put":
                    cli.put(key, value)
                elif method == "delete":
                    cli.delete(key)
                else:
                    cli.get(key)
                sim.sleep(pause)

        tasks = [sim.spawn(partial(client, i), name=f"cli{i}") for i in range(2)]
        for t in tasks:
            t.join()
        sim.sleep(max(0.0, horizon - sim.clock.now()) + 0.05)

    result = sim.run(scenario, plan=plan, monitor_interval=None)
    err = check_linearizable(result.history, KVModel)
    assert err is None, f"seed={seed}: {err}"


@settings(
    max_examples=50,
    deadline=None,
    derandomize=True,
    suppress_health_check=[HealthCheck.function_scoped_fixture],
)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kv_linearizable_under_random_interleavings(seed, tmp_path):
    _kv_lin_scenario(seed, tmp_path / f"s{seed}")
