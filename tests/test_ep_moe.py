"""EP all-to-all MoE dispatch (parallel/ep_moe.py) equivalence tests.

On a 1-device mesh the all_to_alls are identities, so ep output must equal
the GShard einsum path exactly (given no capacity overflow). The true
multi-shard path (8 placeholder devices) runs in a subprocess so the main
test process keeps the single real CPU device.
"""
from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, param_descs
from repro.models.layers import moe
from repro.models.tuning import tuning
from repro.parallel.ep_moe import ep_mesh


def _moe_params(cfg, key):
    from repro.models.layers import moe_descs
    from repro.models.params import init_params as ip

    return ip(moe_descs(cfg), key, jnp.float32)


def test_ep_equals_einsum_on_single_device_mesh():
    import dataclasses as dc

    cfg = get_config("granite_moe_3b_a800m", smoke=True)
    # high capacity factor => nothing drops => paths must agree
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    p = _moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)

    y0, aux0 = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, ep_mesh(mesh), tuning(moe_impl="ep"):
        y1, aux1 = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)

    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5, rtol=2e-5)
    assert abs(float(aux0) - float(aux1)) < 1e-5


def test_ep_gradients_flow():
    import dataclasses as dc

    cfg = get_config("granite_moe_3b_a800m", smoke=True)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    p = _moe_params(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, ep_mesh(mesh), tuning(moe_impl="ep"):
        g = jax.jit(jax.grad(lambda p: jnp.sum(moe(p, x, cfg)[0] ** 2)))(p)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses as dc
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.layers import moe, moe_descs
from repro.models.params import init_params
from repro.models.tuning import tuning
from repro.parallel.ep_moe import ep_mesh

cfg = get_config("granite_moe_3b_a800m", smoke=True)
cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
p = init_params(moe_descs(cfg), jax.random.key(0), jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)
y0, _ = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)
mesh = jax.make_mesh((2, 4), ("data", "model"))  # 4-way expert parallelism
with mesh, ep_mesh(mesh), tuning(moe_impl="ep"):
    y1, _ = jax.jit(lambda p, x: moe(p, x, cfg))(p, x)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-4, rtol=2e-4)
print("EP-4WAY-OK")
"""


def test_ep_multi_shard_subprocess():
    """Real 4-way EP with all_to_alls over 8 placeholder devices."""
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=480,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert "EP-4WAY-OK" in out.stdout, out.stderr[-2000:]
