"""Seed-replay regression suite.

``tests/scenarios/regression_seeds.json`` pins previously-interesting
(scenario, seed) pairs — crash during a group-commit window, partition
during the cross-shard boundary merge, duplicated fragment resends around a
coordinator restart. Each replay re-runs the full deterministic simulation
and its invariant checkers; whenever ``sim/explore.py`` (the CI sim-sweep)
finds a failing seed, its shrunk fault plan gets appended to the JSON file
and is replayed here forever after. The randomised counterpart (hypothesis
over 50 fresh seeds) lives in ``tests/test_sim_properties.py``.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.sim import FaultPlan
from repro.sim.explore import run_one

SCENARIO_FILE = Path(__file__).parent / "scenarios" / "regression_seeds.json"


def _pinned():
    spec = json.loads(SCENARIO_FILE.read_text())
    return [
        pytest.param(
            entry["scenario"],
            int(entry["seed"]),
            FaultPlan.from_json(entry["plan"]) if "plan" in entry else None,
            id=f"{entry['scenario']}-seed{entry['seed']}",
        )
        for entry in spec["pinned"]
    ]


@pytest.mark.parametrize("scenario,seed,plan", _pinned())
def test_pinned_seed_replay(scenario, seed, plan, tmp_path):
    """Replaying a pinned seed must keep every invariant green — run_one
    raises InvariantViolation (with the violating seed) otherwise."""
    run_one(scenario, seed, tmp_path, plan=plan)
