"""§Perf knobs must be semantics-preserving: tuned train steps produce the
same loss/params as untuned (up to fp reassociation), and the flash-decode
path produces the same logits as the baseline decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import cache_descs, decode_step, init_params, param_descs
from repro.models.params import is_desc
from repro.models.tuning import tuning
from repro.optim import AdamWConfig, adamw_init

CFG = get_config("yi_6b", smoke=True)
B, S = 4, 16


def _setup():
    params = init_params(param_descs(CFG), jax.random.key(0), jnp.float32)
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, CFG.vocab_size)
    return params, opt, {"tokens": tokens}


def _run(**tune):
    params, opt, batch = _setup()
    with tuning(**tune):
        step = jax.jit(make_train_step(CFG, AdamWConfig(lr=1e-3), remat="none"))
        p2, o2, loss = step(params, opt, batch)
    return float(loss), p2


def test_chunked_loss_matches_full():
    loss0, p0 = _run()
    loss1, p1 = _run(loss_chunk=4)
    assert abs(loss0 - loss1) < 1e-4
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, p1
    )
    assert max(jax.tree_util.tree_leaves(d)) < 1e-4


def test_microbatch_matches_full():
    loss0, p0 = _run()
    loss1, p1 = _run(microbatch=2)
    assert abs(loss0 - loss1) < 1e-4
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p0, p1
    )
    # Adam at step 1 behaves like sign(g): fp reassociation of the
    # microbatch sum flips near-zero grads, so compare post-update params
    # at the scale of one lr step, not exact fp.
    assert max(jax.tree_util.tree_leaves(d)) < 2e-3


def test_constrain_activations_is_noop_numerically():
    loss0, _ = _run()
    loss1, _ = _run(constrain_activations=True)
    assert abs(loss0 - loss1) < 1e-5


def test_flash_decode_path_matches_baseline():
    params = init_params(param_descs(CFG), jax.random.key(0), jnp.float32)
    cdescs = cache_descs(CFG, batch=2, max_len=8)
    cache0 = jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape, jnp.float32), cdescs, is_leaf=is_desc
    )
    tok = jnp.ones((2, 1), jnp.int32)

    def roll(flag):
        cache = cache0
        outs = []
        with tuning(decode_seq_constraint=flag):
            for i in range(4):
                logits, cache = jax.jit(
                    lambda p, c, t, idx: decode_step(CFG, p, c, t, idx)
                )(params, cache, tok, jnp.asarray(i, jnp.int32))
                outs.append(np.asarray(logits))
        return np.stack(outs)

    np.testing.assert_allclose(roll(False), roll(True), atol=1e-4, rtol=1e-4)
