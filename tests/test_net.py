"""Transport-fabric tests (repro.net.transport): RPC semantics, per-link
fault injection (latency / loss / reorder / partition), exactly-once
processing under at-least-once delivery, and batched delivery.

The fault-injection tests run under the deterministic simulation runtime
(``repro.sim``): latency, retry backoff, and partition windows elapse in
virtual time, so a test that used to burn ~1.5s of wall clock on sleeps now
runs in milliseconds and replays identically from its seed.
``TestSimTransportRPC.test_roundtrip_and_latency`` stays on the real clock
as the wall-clock smoke test for this module."""
from __future__ import annotations

import threading
import time

import pytest

from repro.core.sthread import DelayMessage
from repro.net import DirectTransport, LinkSpec, SimTransport
from repro.sim import SimScheduler


@pytest.fixture
def sim():
    transports = []

    def make(**kw) -> SimTransport:
        t = SimTransport(**kw)
        transports.append(t)
        return t

    yield make
    for t in transports:
        t.close()


def run_virtual(body, seed: int = 0):
    """Run ``body(sched, make_transport)`` as the root task of a seeded
    simulation; transports draw their clock (and worker tasks) from the
    scheduler, so every latency/retry/partition wait is virtual."""
    sched = SimScheduler(seed=seed)

    def main():
        transports = []

        def make(**kw) -> SimTransport:
            t = SimTransport(clock=sched.clock, **kw)
            transports.append(t)
            return t

        try:
            return body(sched, make)
        finally:
            for t in transports:
                t.close()

    return sched.run(main)


class TestDirectTransport:
    def test_rpc(self):
        t = DirectTransport()
        t.register("svc", lambda method, *a, **k: (method, a, k))
        assert t.call("cli", "svc", "ping", 1, x=2) == ("ping", (1,), {"x": 2})

    def test_delay_retry(self):
        t = DirectTransport(delay_backoff=0.0)
        attempts = []

        def handler(method, *a, **k):
            attempts.append(method)
            if len(attempts) < 3:
                raise DelayMessage()
            return "caught-up"

        t.register("svc", handler)
        assert t.call("cli", "svc", "go") == "caught-up"
        assert len(attempts) == 3


class TestSimTransportRPC:
    def test_roundtrip_and_latency(self, sim):
        t = sim(default_link=LinkSpec(latency_ms=20.0))
        t.register("svc", lambda method, *a, **k: sum(a))
        t0 = time.monotonic()
        assert t.call("cli", "svc", "add", 1, 2, 3) == 6
        # request + reply each cross a 20 ms link
        assert time.monotonic() - t0 >= 0.035

    def test_handler_exception_propagates(self, sim):
        t = sim()

        def handler(method, *a, **k):
            raise ValueError("boom")

        t.register("svc", handler)
        with pytest.raises(ValueError, match="boom"):
            t.call("cli", "svc", "go")

    def test_unknown_endpoint_times_out_not_hangs(self, sim):
        t = sim(call_timeout=0.2)
        from repro.net import TransportError

        with pytest.raises(TransportError):
            t.call("cli", "nobody", "go")

    def test_delay_reply_is_not_cached(self, sim):
        """A delayed message must be re-processed on retry (Def 4.3): the
        dedup cache must not swallow the redelivery."""
        t = sim(delay_backoff=0.0, retry_timeout=0.02)
        invocations = []

        def handler(method, *a, **k):
            invocations.append(method)
            if len(invocations) < 3:
                raise DelayMessage()
            return "ok"

        t.register("svc", handler)
        assert t.call("cli", "svc", "m") == "ok"
        assert len(invocations) == 3


class TestFaultInjection:
    """Ported to virtual time: the waits below (retry backoff under 30%
    loss, 0.15s partition windows, a 50ms reorder delay) cost no wall clock
    and replay deterministically from the scheduler seed."""

    def test_exactly_once_processing_under_loss(self):
        """30% loss on requests AND replies: every call still returns, and
        the handler's side effect lands exactly once per logical message."""

        def body(sched, make):
            t = make(
                seed=42,
                default_link=LinkSpec(latency_ms=0.1, loss_prob=0.3),
                retry_timeout=0.01,
                call_timeout=10.0,
            )
            state = {"count": 0}

            def handler(method, *a, **k):
                state["count"] += 1
                return state["count"]

            t.register("svc", handler)
            n = 40
            results = [t.call("cli", "svc", "inc") for _ in range(n)]
            assert state["count"] == n  # retries never double-processed
            assert sorted(results) == list(range(1, n + 1))
            st = t.stats()
            assert st["dropped_loss"] > 0 and st["retries"] > 0

        run_virtual(body)

    def test_partition_drops_then_heals(self):
        def body(sched, make):
            t = make(retry_timeout=0.01)
            t.register("svc", lambda method, *a, **k: "pong")
            t.partition({"svc"})
            with pytest.raises(TimeoutError):
                t.call("cli", "svc", "ping", timeout=0.15)
            assert t.stats()["dropped_partition"] > 0
            t.heal()
            assert t.call("cli", "svc", "ping") == "pong"

        run_virtual(body)

    def test_same_group_unaffected_by_partition(self):
        def body(sched, make):
            t = make()
            t.register("a", lambda method, *arg, **k: "from-a")
            t.register("b", lambda method, *arg, **k: "from-b")
            t.partition({"a", "cli"})
            assert t.call("cli", "a", "x") == "from-a"  # same island
            with pytest.raises(TimeoutError):
                t.call("cli", "b", "x", timeout=0.15)  # across the cut

        run_virtual(body)

    def test_reorder_overtakes(self):
        """A reordered message is overtaken by a later send on a fast link."""

        def body(sched, make):
            t = make()
            t.set_link(
                "slowpoke", "svc", latency_ms=0.0, reorder_prob=1.0, reorder_ms=50.0
            )
            order = []
            done = sched.clock.event()

            def handler(method, *a, **k):
                order.append(method)
                if len(order) == 2:
                    done.set()
                return None

            t.register("svc", handler)
            t.cast("slowpoke", "svc", "first")
            t.cast("cli", "svc", "second")
            assert done.wait(2.0)
            assert order == ["second", "first"]

        run_virtual(body)


class TestBatchedDelivery:
    def test_messages_coalesce_into_batches(self, sim):
        """Messages landing inside one latency window drain in one worker
        wakeup (Netherite-style batching): far fewer batches than messages."""
        t = sim(default_link=LinkSpec(latency_ms=30.0), batch_size=64)
        n = 50
        seen = []
        done = threading.Event()

        def handler(method, *a, **k):
            seen.append(a[0])
            if len(seen) == n:
                done.set()
            return None

        t.register("svc", handler)
        for i in range(n):
            t.cast("cli", "svc", "m", i)
        assert done.wait(5.0)
        assert sorted(seen) == list(range(n))
        st = t.stats()
        assert st["delivered_msgs"] == n
        assert st["delivered_batches"] <= n // 5  # strongly coalesced
        assert st["mean_batch"] >= 5.0

    def test_reregister_replaces_handler(self, sim):
        t = sim()
        t.register("svc", lambda method, *a, **k: "old")
        assert t.call("cli", "svc", "x") == "old"
        t.register("svc", lambda method, *a, **k: "new")  # restarted incarnation
        assert t.call("cli", "svc", "x") == "new"


class TestReportRequeueDedup:
    """PR-4 regression: when a report RPC raises (timeout) AFTER the
    coordinator actually processed the delivery — lost reply, or a late
    in-flight envelope landing after the call's deadline — the runtime's
    requeue path resends the same fragments under a fresh transport message
    id, so receiver-side msg dedup cannot catch the duplicate. The
    coordinator must drop it by (so_id, world, seq) instead of
    double-ingesting."""

    def _cluster(self, tmp_path):
        from repro.core import LocalCluster

        return LocalCluster(
            tmp_path / "c", refresh_interval=None, group_commit_interval=99
        )

    def test_requeued_report_not_double_processed(self, tmp_path):
        from repro.services.counter import CounterStateObject

        from conftest import wait_committed

        with self._cluster(tmp_path) as cluster:
            so = cluster.add("a", lambda: CounterStateObject(tmp_path / "so_a"))
            real = cluster.coordinator
            delivered = []

            class DeliverThenTimeout:
                """Transport model of the bug: the request reaches the
                coordinator, the reply is lost, the caller sees a timeout."""

                fail_next = 0

                def report(self, so_id, reports):
                    real.report(so_id, reports)  # delivery DID happen
                    delivered.append([r.vertex for r in reports])
                    if self.fail_next:
                        self.fail_next -= 1
                        raise TimeoutError("reply lost after delivery")

                def __getattr__(self, name):
                    return getattr(real, name)

            proxy = DeliverThenTimeout()
            so.runtime.coordinator = proxy

            so.increment(None)
            so.runtime.maybe_persist(force=True)
            assert wait_committed(so, 1)
            proxy.fail_next = 1
            import pytest as _pytest

            with _pytest.raises(TimeoutError):
                so.runtime._flush_reports()  # requeue fires
            # the retry resends the SAME fragment (fresh msg id in the real
            # fabric) and must be dropped server-side, not re-ingested
            so.runtime._flush_reports()
            assert len(delivered) == 2  # genuinely delivered twice...
            assert delivered[0] == delivered[1]
            assert real.stats()["dup_reports_dropped"] >= 1  # ...counted once
            # queue drained: nothing left to resend a third time
            assert so.runtime._report_queue == []
            # and the graph view is coherent (one vertex per label)
            st = real.stats()
            assert st["graph_vertices"] == len(so.runtime.stats()["labels"])

    def test_flush_batch_dedups_by_vertex(self, tmp_path):
        from repro.core.ids import PersistReport, Vertex
        from repro.services.counter import CounterStateObject

        with self._cluster(tmp_path) as cluster:
            so = cluster.add("a", lambda: CounterStateObject(tmp_path / "so_a"))
            batches = []
            real = cluster.coordinator

            class Recording:
                def report(self, so_id, reports):
                    batches.append(list(reports))
                    real.report(so_id, reports)

                def __getattr__(self, name):
                    return getattr(real, name)

            so.runtime.coordinator = Recording()
            v = Vertex("a", 0, 0)
            with so.runtime._mu:
                so.runtime._report_queue = [
                    PersistReport(v, (), seq=5),
                    PersistReport(v, (), seq=5),  # duplicate queue entry
                ]
            so.runtime._flush_reports()
            assert len(batches[-1]) == 1  # batch canonicalized client-side

    def test_seen_compaction_is_per_world(self, tmp_path):
        """Compaction of the seen-set must floor per world: a restarted
        incarnation starts a new world at seq 0, and a global floor computed
        from the old world's high seqs would erase its live dedup window
        (code-review regression)."""
        from repro.core.ids import PersistReport, Vertex

        with self._cluster(tmp_path) as cluster:
            coord = cluster.coordinator
            # a long-lived previous incarnation: world 0, seqs up to ~17k
            coord._report_seen["x"] = {(0, s) for s in range(17000)}
            r = PersistReport(Vertex("x", 1, 0), (), seq=0)
            coord.report("x", [r])  # new world entry + triggers compaction
            assert (1, 0) in coord._report_seen["x"]
            coord.report("x", [r])  # transport-retry duplicate
            assert coord.stats()["dup_reports_dropped"] == 1

    def test_fragment_resends_never_deduped(self, tmp_path):
        """seq=-1 (connect/fragment resends rebuilt from disk) must always
        be ingestible — a restarted coordinator depends on full resends."""
        from repro.core.ids import PersistReport, Vertex

        with self._cluster(tmp_path) as cluster:
            coord = cluster.coordinator
            r = PersistReport(Vertex("x", 0, 0), ())  # seq=-1
            coord.report("x", [r])
            coord.report("x", [r])
            assert coord.stats()["dup_reports_dropped"] == 0


class TestFragmentGC:
    """PR-5 regression (DESIGN.md §11): fragment resends must ship O(live)
    state — versions strictly below the durable exposure floor (whose
    watermark the coordinator's snapshot already records) and stale blobs a
    decision has invalidated stay home, and the coordinator must recover a
    boundary at least as fresh from the GC'd resend alone."""

    def _capture_resends(self, cluster, so):
        """Wrap the runtime's coordinator handle, recording resent batches
        (installed AFTER restart_coordinator, which refreshes the handle)."""
        real = so.runtime.coordinator
        captured = []

        class Recording:
            def receive_fragments(self, so_id, fragments):
                captured.append(list(fragments))
                real.receive_fragments(so_id, fragments)

            def __getattr__(self, name):
                return getattr(real, name)

        so.runtime.coordinator = Recording()
        return captured

    def test_resend_skips_below_floor_keeps_anchor(self, tmp_path):
        from repro.core import LocalCluster
        from repro.core.ids import encode_metadata
        from repro.services.counter import CounterStateObject

        from conftest import settle, wait_committed

        with LocalCluster(
            tmp_path / "c", refresh_interval=None, group_commit_interval=99
        ) as cluster:
            so = cluster.add("a", lambda: CounterStateObject(tmp_path / "so_a"))
            for _ in range(4):
                so.increment(None)
                assert wait_committed(so, so.runtime.maybe_persist(force=True))
            assert settle(
                lambda: so.runtime.boundary.get("a", -1) >= 2, cluster
            ), "boundary never advanced"
            floor = so.runtime.boundary["a"]
            before = cluster.coordinator.current_boundary()
            # simulate a lagging prune: below-floor history still on disk
            # (the background Prune has not caught up with the boundary)
            for v in range(floor):
                so.store.write(v, b"0", encode_metadata(0, v, []))

            cluster.restart_coordinator()
            captured = self._capture_resends(cluster, so)
            assert settle(
                lambda: cluster.coordinator.current_boundary() is not None, cluster
            ), "coordinator never recovered"
            assert captured, "restart must trigger a fragment resend"
            # the anchor: greatest persisted label <= the floor watermark
            anchor = max(l for l in so.runtime.stats()["labels"] if l <= floor)
            versions = sorted(r.vertex.version for r in captured[0])
            assert all(v >= anchor for v in versions), versions  # GC'd resend
            assert versions[0] == anchor, versions  # ...but the anchor ships
            after = cluster.coordinator.current_boundary()
            assert after.get("a", -1) >= before.get("a", -1)  # nothing lost

    def test_resend_skips_decision_invalidated_stale_blobs(self, tmp_path):
        """An innocent member rolled back below its persisted top keeps the
        stale blobs on disk (paper §5.3 note) — but must not keep shipping
        them on every resend: the decision already proves they are dead."""
        from repro.core import LocalCluster
        from repro.core.ids import Vertex
        from repro.services.counter import CounterStateObject

        from conftest import settle, wait_committed

        with LocalCluster(
            tmp_path / "c", refresh_interval=None, group_commit_interval=99
        ) as cluster:
            a = cluster.add("a", lambda: CounterStateObject(tmp_path / "so_a"))
            b = cluster.add("b", lambda: CounterStateObject(tmp_path / "so_b"))
            # b persists state depending on a's IN-MEMORY (never persisted)
            # version; a's crash then invalidates b's persisted suffix.
            out = a.increment(None)
            assert out is not None
            _, h = out
            assert b.increment(h) is not None
            assert wait_committed(b, b.runtime.maybe_persist(force=True))
            stale_top = b.runtime.stats()["committed"]
            cluster.kill("a")
            assert settle(lambda: b.runtime.world >= 1, cluster)
            idx = b.runtime._dindex
            assert idx.invalidates(Vertex("b", 0, stale_top)), "setup: no rollback"

            cluster.restart_coordinator()
            captured = self._capture_resends(cluster, b)
            assert settle(
                lambda: cluster.coordinator.current_boundary() is not None, cluster
            )
            assert captured
            resent = captured[0]
            assert all(not idx.invalidates(r.vertex) for r in resent), resent
