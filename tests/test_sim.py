"""Deterministic simulation runtime tests (repro.sim, DESIGN.md §8):

* SimScheduler primitives — virtual sleep jumps time (no wall-clock cost),
  cooperative events / locks / conditions, deadlock + virtual-timeout
  detection, background-task failure surfacing;
* the acceptance property — the same scenario run twice with the same seed
  yields BYTE-IDENTICAL event traces, while two different seeds diverge;
* FaultPlan — seed-derived schedules are deterministic, serialisation
  round-trips, and ``without`` (the shrinking primitive) works;
* invariant checkers — linearizability (Wing–Gong), exactly-once counter
  acks, watermark monotonicity, shard-log prefix consistency — each on both
  a passing and a failing example;
* SimCluster — the whole DSE stack (runtime, coordinator shards, transport)
  under virtual time, running faster than the virtual seconds it simulates.
"""
from __future__ import annotations

import json
import time

import pytest

from repro.core.clock import RealClock
from repro.sim import (
    FaultPlan,
    KVModel,
    Op,
    PENDING,
    SimCluster,
    SimDeadlock,
    SimScheduler,
    SimTaskError,
    SimTimeout,
    WatermarkMonitor,
    check_exactly_once_counter,
    check_linearizable,
    check_shard_logs,
)
from repro.sim.explore import run_one


# --------------------------------------------------------------------------- #
# scheduler primitives                                                         #
# --------------------------------------------------------------------------- #
class TestSimScheduler:
    def test_virtual_sleep_costs_no_wall_clock(self):
        sched = SimScheduler(seed=0)
        t0 = time.monotonic()

        def main():
            sched.clock.sleep(60.0)  # a whole virtual minute
            return sched.now

        assert sched.run(main) == pytest.approx(60.0)
        assert time.monotonic() - t0 < 5.0  # ran in wall milliseconds

    def test_time_jumps_to_next_deadline(self):
        sched = SimScheduler(seed=0)
        wakes = []

        def sleeper(d):
            sched.clock.sleep(d)
            wakes.append(sched.now)

        def main():
            ts = [sched.clock.spawn(lambda d=d: sleeper(d)) for d in (5.0, 1.0, 3.0)]
            for t in ts:
                t.join()

        sched.run(main)
        assert wakes == [1.0, 3.0, 5.0]  # deadline order, not spawn order

    def test_event_set_wakes_waiter(self):
        sched = SimScheduler(seed=0)

        def main():
            ev = sched.clock.event()
            got = []

            def waiter():
                got.append(ev.wait(10.0))

            t = sched.clock.spawn(waiter)
            sched.clock.sleep(0.5)
            ev.set()
            t.join()
            return got, sched.now

        got, now = sched.run(main)
        assert got == [True]
        assert now == pytest.approx(0.5)  # woke at set(), not the timeout

    def test_event_wait_times_out_in_virtual_time(self):
        sched = SimScheduler(seed=0)

        def main():
            ev = sched.clock.event()
            ok = ev.wait(2.5)
            return ok, sched.now

        ok, now = sched.run(main)
        assert not ok
        assert now == pytest.approx(2.5)

    def test_lock_mutual_exclusion(self):
        sched = SimScheduler(seed=3)

        def main():
            mu = sched.clock.lock()
            trace = []

            def worker(name):
                for _ in range(5):
                    with mu:
                        trace.append((name, "in"))
                        sched.clock.sleep(0.01)  # hold across a yield
                        trace.append((name, "out"))

            ts = [sched.clock.spawn(lambda n=n: worker(n)) for n in "ab"]
            for t in ts:
                t.join()
            return trace

        trace = sched.run(main)
        # never two "in"s without an "out" between them
        depth = 0
        for _, what in trace:
            depth += 1 if what == "in" else -1
            assert depth in (0, 1)

    def test_condition_wait_for(self):
        sched = SimScheduler(seed=0)

        def main():
            cv = sched.clock.condition()
            box = {"v": 0}

            def producer():
                sched.clock.sleep(1.0)
                with cv:
                    box["v"] = 42
                    cv.notify_all()

            sched.clock.spawn(producer)
            with cv:
                assert cv.wait_for(lambda: box["v"] == 42, timeout=5.0)
            return box["v"], sched.now

        v, now = sched.run(main)
        assert v == 42
        assert now == pytest.approx(1.0)

    def test_deadlock_detected(self):
        sched = SimScheduler(seed=0)

        def main():
            sched.clock.event().wait()  # no timeout, nobody will set it

        with pytest.raises(SimDeadlock):
            sched.run(main)

    def test_virtual_timeout_detected(self):
        sched = SimScheduler(seed=0)

        def main():
            sched.clock.sleep(10_000.0)

        with pytest.raises(SimTimeout):
            sched.run(main, max_virtual_time=60.0)

    def test_background_task_failure_surfaces(self):
        sched = SimScheduler(seed=0)

        def main():
            def dies():
                raise ValueError("background boom")

            t = sched.clock.spawn(dies)
            t.join()

        with pytest.raises(SimTaskError, match="background boom"):
            sched.run(main)

    def test_root_task_exception_propagates(self):
        sched = SimScheduler(seed=0)

        def main():
            raise KeyError("root boom")

        with pytest.raises(KeyError):
            sched.run(main)

    def test_primitive_outside_task_rejected(self):
        sched = SimScheduler(seed=0)
        with pytest.raises(RuntimeError, match="outside a simulation task"):
            sched.clock.sleep(1.0)


# --------------------------------------------------------------------------- #
# determinism: the acceptance property                                         #
# --------------------------------------------------------------------------- #
def _chaotic_workload(sched: SimScheduler):
    """A workload with real scheduling freedom: the trace differs between
    seeds unless the scheduler's RNG pins every choice."""

    def main():
        mu = sched.clock.lock()
        ev = sched.clock.event()
        out = []

        def worker(i):
            for j in range(4):
                with mu:
                    out.append((i, j, round(sched.now, 6)))
                sched.clock.sleep(0.001 * ((i + j) % 3 + 1))
            if i == 0:
                ev.set()

        ts = [sched.clock.spawn(lambda i=i: worker(i)) for i in range(4)]
        ev.wait(5.0)
        for t in ts:
            t.join()
        return out

    return sched.run(main)


class TestDeterminism:
    def test_same_seed_identical_trace_scheduler(self):
        runs = []
        for _ in range(2):
            sched = SimScheduler(seed=1234)
            value = _chaotic_workload(sched)
            runs.append((value, sched.trace_text()))
        assert runs[0][0] == runs[1][0]
        assert runs[0][1].encode() == runs[1][1].encode()  # byte-identical

    def test_different_seeds_diverge_scheduler(self):
        traces = []
        for seed in (1, 2):
            sched = SimScheduler(seed=seed)
            _chaotic_workload(sched)
            traces.append(sched.trace_text())
        assert traces[0] != traces[1]

    def test_same_seed_identical_trace_full_stack(self, tmp_path):
        """Acceptance criterion on a REAL scenario: the whole DSE stack —
        sharded coordinator, transport faults, fault plan, recovery — replays
        byte-identically from one seed, and a different seed diverges."""
        r1 = run_one("partition_merge", 7, tmp_path / "w1")
        r2 = run_one("partition_merge", 7, tmp_path / "w2")
        r3 = run_one("partition_merge", 8, tmp_path / "w3")
        assert r1.trace.encode() == r2.trace.encode()
        assert r1.events == r2.events
        assert r1.virtual_time == r2.virtual_time
        assert r1.trace != r3.trace


# --------------------------------------------------------------------------- #
# fault plans                                                                  #
# --------------------------------------------------------------------------- #
class TestFaultPlan:
    def test_random_is_seed_deterministic(self):
        kw = dict(so_ids=["a", "b"], horizon=1.0, n_shards=2, allow_crash=True)
        p1 = FaultPlan.random(42, **kw)
        p2 = FaultPlan.random(42, **kw)
        p3 = FaultPlan.random(43, **kw)
        assert p1.dumps() == p2.dumps()
        assert p1.dumps() != p3.dumps()

    def test_serialisation_round_trip(self):
        plan = (
            FaultPlan()
            .crash(0.1, "prod")
            .partition(0.2, ["coord/0"], ["coord/1"])
            .heal(0.4)
            .method_link(0.3, "report", loss_prob=0.5)
        )
        again = FaultPlan.loads(plan.dumps())
        assert again.to_json() == plan.to_json()
        assert again.loses_state()

    def test_without_drops_events(self):
        plan = FaultPlan().crash(0.1, "a").heal(0.2).crash(0.3, "b")
        shrunk = plan.without([0, 2])
        kinds = [e.kind for e in shrunk.sorted_events()]
        assert kinds == ["heal"]

    def test_healing_epilogue_always_present(self):
        plan = FaultPlan.random(9, so_ids=["x"], horizon=2.0, n_shards=2)
        tail = [e.kind for e in plan.sorted_events() if e.at == 2.0]
        assert "heal" in tail


# --------------------------------------------------------------------------- #
# invariant checkers                                                           #
# --------------------------------------------------------------------------- #
class TestLinearizability:
    def test_accepts_sequential_history(self):
        h = [
            Op("c1", "put", ("k", "v1"), "ok", 0.0, 1.0),
            Op("c2", "get", ("k",), "v1", 2.0, 3.0),
            Op("c1", "put", ("k", "v2"), "ok", 4.0, 5.0),
            Op("c2", "get", ("k",), "v2", 6.0, 7.0),
        ]
        assert check_linearizable(h, KVModel) is None

    def test_accepts_concurrent_overlap(self):
        # put and get overlap: the get may see either value
        h = [
            Op("c1", "put", ("k", "v1"), "ok", 0.0, 2.0),
            Op("c2", "get", ("k",), None, 1.0, 1.5),  # linearizes before the put
        ]
        assert check_linearizable(h, KVModel) is None

    def test_rejects_stale_read(self):
        # put completed strictly before the get was invoked: the get MUST
        # observe v1, so None is a linearizability violation.
        h = [
            Op("c1", "put", ("k", "v1"), "ok", 0.0, 1.0),
            Op("c2", "get", ("k",), None, 2.0, 3.0),
        ]
        assert check_linearizable(h, KVModel) is not None

    def test_rejects_value_from_nowhere(self):
        h = [Op("c1", "get", ("k",), "ghost", 0.0, 1.0)]
        assert check_linearizable(h, KVModel) is not None

    def test_pending_op_may_or_may_not_apply(self):
        # a pending put (crashed mid-flight) explains EITHER read outcome
        for observed in ("v1", None):
            h = [
                Op("c1", "put", ("k", "v1"), PENDING, 0.0, None),
                Op("c2", "get", ("k",), observed, 1.0, 2.0),
            ]
            assert check_linearizable(h, KVModel) is None, observed


class TestOtherInvariants:
    def test_exactly_once_counter(self):
        assert check_exactly_once_counter([1, 2, 3], 3) is None
        assert check_exactly_once_counter([1, 2, 2], 3) is not None  # dup ack
        assert check_exactly_once_counter([1, 2, 4], 3) is not None  # gap
        assert check_exactly_once_counter([1, 2, 3], 5) is not None  # overshoot

    def test_watermark_monitor(self):
        ok = WatermarkMonitor()
        ok.sample(0.0, 0, {"a": 0})
        ok.sample(0.1, 0, {"a": 2})
        ok.sample(0.2, 1, {"a": 1})  # retreat allowed: epoch advanced
        assert ok.check() == []

        bad = WatermarkMonitor()
        bad.sample(0.0, 0, {"a": 2})
        bad.sample(0.1, 0, {"a": 1})  # retreat WITHIN the epoch
        assert bad.check()

    def test_shard_logs_prefix_consistency(self, tmp_path):
        rec = {"type": "decision", "fsn": 1, "world": 1, "targets": {"a": 0}}
        (tmp_path / "shard0.jsonl").write_text(json.dumps(rec) + "\n")
        (tmp_path / "shard1.jsonl").write_text(json.dumps(rec) + "\n")
        assert check_shard_logs(tmp_path) == []
        # shard1 diverges on a shared fsn => violation
        other = dict(rec, targets={"a": 99})
        (tmp_path / "shard1.jsonl").write_text(json.dumps(other) + "\n")
        assert check_shard_logs(tmp_path)

    def test_shard_logs_missing_decision(self, tmp_path):
        rec = {"type": "decision", "fsn": 1, "world": 1, "targets": {}}
        (tmp_path / "shard0.jsonl").write_text(json.dumps(rec) + "\n")
        (tmp_path / "shard1.jsonl").write_text("")
        errors = check_shard_logs(tmp_path)
        assert any("missing" in e for e in errors)


# --------------------------------------------------------------------------- #
# SimCluster: the whole stack under virtual time                               #
# --------------------------------------------------------------------------- #
class TestSimCluster:
    def test_counter_chain_under_virtual_time(self, tmp_path):
        from repro.services.counter import CounterStateObject

        sim = SimCluster(tmp_path, seed=5, n_shards=2)
        t0 = time.monotonic()

        def scenario(sim: SimCluster):
            sim.add("ctr", lambda: CounterStateObject(sim.root / "so_ctr"))
            h = None
            for _ in range(10):
                v, h = sim.send(None, "ctr", "increment", h)
            sim.sleep(30.0)  # virtual: free
            return v

        result = sim.run(scenario)
        assert result.value == 10
        assert result.virtual_time >= 30.0
        assert time.monotonic() - t0 < 30.0  # far less wall than virtual

    def test_fault_plan_drives_crash_and_recovery(self, tmp_path):
        from repro.services.counter import CounterStateObject

        plan = FaultPlan().crash(0.5, "ctr")
        sim = SimCluster(tmp_path, seed=5, n_shards=2)

        def scenario(sim: SimCluster):
            sim.add("ctr", lambda: CounterStateObject(sim.root / "so_ctr"))
            sim.send(None, "ctr", "increment", None)
            sim.sleep(1.0)  # ride through the crash at t=0.5
            ok = sim.settle(
                lambda: sim.get("ctr").runtime.world >= 1, timeout=30.0
            )
            return ok, sim.get("ctr").runtime.world

        ok, world = sim.run(scenario, plan=plan).value
        assert ok and world >= 1

    def test_scenarios_registry_smoke(self, tmp_path):
        """Every named explore scenario runs green on seed 0 (each run also
        exercises its invariant checkers — run_one raises on violation)."""
        from repro.sim.explore import SCENARIOS

        for name in sorted(SCENARIOS):
            run_one(name, 0, tmp_path)


# --------------------------------------------------------------------------- #
# clock contract (real side)                                                   #
# --------------------------------------------------------------------------- #
class TestRealClock:
    def test_real_clock_contract_smoke(self):
        c = RealClock()
        t0 = c.now()
        c.sleep(0.001)
        assert c.now() >= t0 + 0.001
        ev = c.event()
        assert not ev.wait(0.001)
        ev.set()
        assert ev.wait(0.001)
        done = []
        h = c.spawn(lambda: done.append(1))
        h.join(2.0)
        assert done == [1]
