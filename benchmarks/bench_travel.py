"""TravelReservations (paper Fig. 9): end-to-end workflow latency vs the
number of services, speculative vs synchronous-persistence baseline, plus a
throughput-scaling sweep.

Baseline simulates Temporal/Beldi/Boki-class systems by deploying every
service on the synchronous DurableRuntime (``runtime="durable"``): the same
number of synchronous persists current durable-execution engines pay
(paper §6.1) — see ``benchmarks/bench_eval.py`` for the per-op latency /
persistence-latency sweep version of this comparison.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import LocalCluster
from repro.services import SpeculativeKVStore, WorkflowEngine

from .common import emit, summarize, timer

GC = 0.010  # paper's 10 ms group commit


def _setup(root: Path, n_services: int, speculative: bool):
    runtime = "dse" if speculative else "durable"
    cluster = LocalCluster(root, group_commit_interval=GC, runtime=runtime)
    kvs = []
    for i in range(n_services):
        kv = cluster.add(
            f"svc{i}", (lambda i=i: SpeculativeKVStore(root / f"kv{i}"))
        )
        kv.stock("item", 10**9)
        kvs.append(kv)
    wf = cluster.add("wf", lambda: WorkflowEngine(root / "wf"))
    return cluster, wf, kvs


def _run_workflows(wf, kvs, n: int, lat_ms):
    for i in range(n):
        wf_id = f"wf{i}"
        steps = [
            (lambda hdr, kv=kv, w=wf_id: kv.try_reserve("item", w, hdr)) for kv in kvs
        ]
        with timer(lat_ms):
            out = wf.run_workflow(wf_id, steps)
            assert out is not None


def run(quick: bool = True, csv_path=None):
    rows = []
    n_wf = 15 if quick else 60
    for n_services in (1, 2, 3, 4, 5):
        for spec in (True, False):
            with tempfile.TemporaryDirectory() as td:
                cluster, wf, kvs = _setup(Path(td), n_services, spec)
                try:
                    lat = []
                    _run_workflows(wf, kvs, n_wf, lat)
                    tag = "dse" if spec else "baseline"
                    rows.append(summarize(f"travel/{tag}/services={n_services}", lat))
                finally:
                    cluster.shutdown()
    # throughput scaling at 3 services (paper Fig. 9 right)
    for spec in (True, False):
        with tempfile.TemporaryDirectory() as td:
            cluster, wf, kvs = _setup(Path(td), 3, spec)
            try:
                t0 = time.perf_counter()
                lat = []
                _run_workflows(wf, kvs, n_wf, lat)
                dt = time.perf_counter() - t0
                tag = "dse" if spec else "baseline"
                rows.append({
                    "name": f"travel/{tag}/throughput",
                    "workflows_per_s": round(n_wf / dt, 1),
                })
            finally:
                cluster.shutdown()
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
