"""EventProcessing (paper Fig. 10): a 3-stage streaming pipeline over the
speculative event broker. Reports end-to-end event latency AND bytes written
to storage while varying the group-commit period — the storage saving grows
with the period because produced+consumed+acked events never reach disk.

The non-speculative baseline (original DARQ behaviour) blocks consumption
until the produced events are durable (wait_durable on the producer side).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import LocalCluster
from repro.services import EventBroker

from .common import emit, pctl, summarize, timer

TOPICS = ["t0", "t1", "t2"]  # source -> stage1 -> stage2


def _pipeline(root: Path, gc: float, speculative: bool, n_events: int):
    cluster = LocalCluster(root, group_commit_interval=gc)
    br = cluster.add("broker", lambda: EventBroker(root / "br", topics=TOPICS))
    lat_ms = []
    try:
        produced = 0
        batch = 8
        while produced < n_events:
            evts = [f"e{produced + i}".encode() for i in range(batch)]
            t0 = time.perf_counter()
            _, h = br.produce("t0", evts)
            if not speculative:
                # baseline: events are consumable only once durable
                assert br.StartAction(h)
                assert br.wait_durable(timeout=10.0)
                h = br.EndAction()
            # stage 1: consume t0 -> produce t1
            for src, dst, grp in (("t0", "t1", "g1"), ("t1", "t2", "g2")):
                out = br.consume(grp, src, max_n=batch, header=h)
                assert out is not None
                evs, h2 = out
                _, h3 = br.produce(dst, [d for _, d in evs], header=h2)
                if not speculative:
                    assert br.StartAction(h3)
                    assert br.wait_durable(timeout=10.0)
                    h3 = br.EndAction()
                br.ack(grp, src, evs[-1][0], header=h3)
                h = h3
            # sink: consume t2 (external consumer => barrier in spec mode)
            out = br.consume("sink", "t2", max_n=batch, header=h)
            evs, h4 = out
            if speculative:
                assert br.StartAction(h4)
                assert br.wait_durable(timeout=10.0)
                h4 = br.EndAction()
            br.ack("sink", "t2", evs[-1][0], header=h4)
            lat_ms.append((time.perf_counter() - t0) * 1e3 / batch)
            produced += batch
        cluster.refresh_all()
        time.sleep(2 * gc)  # let the final group commit drain
        bytes_written = br.storage_bytes_written()
        skipped = br.entries_skipped()
    finally:
        cluster.shutdown()
    return lat_ms, bytes_written, skipped


def run(quick: bool = True, csv_path=None):
    rows = []
    n = 96 if quick else 512
    for gc in (0.005, 0.02, 0.05):
        for spec in (True, False):
            with tempfile.TemporaryDirectory() as td:
                lat, bw, sk = _pipeline(Path(td), gc, spec, n)
                tag = "dse" if spec else "baseline"
                s = summarize(f"event/{tag}/gc={int(gc*1e3)}ms", lat)
                s["storage_bytes"] = bw
                s["events_never_stored"] = sk
                rows.append(s)
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
