"""Shared benchmark utilities."""
from __future__ import annotations

import statistics
import time
from contextlib import contextmanager
from typing import Dict, List


def pctl(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(p / 100 * (len(xs) - 1)))))
    return xs[k]


def summarize(name: str, xs_ms: List[float]) -> Dict[str, float]:
    return {
        "name": name,
        "n": len(xs_ms),
        "mean_ms": statistics.fmean(xs_ms) if xs_ms else float("nan"),
        "p50_ms": pctl(xs_ms, 50),
        "p95_ms": pctl(xs_ms, 95),
    }


@contextmanager
def timer(out: List[float]):
    t0 = time.perf_counter()
    yield
    out.append((time.perf_counter() - t0) * 1e3)


# Rows from the last emit() calls, drained by benchmarks.run for --json
# (suite → "row.metric" → value) machine-readable output.
_collected: List[Dict] = []


def take_collected() -> List[Dict]:
    out = list(_collected)
    _collected.clear()
    return out


def emit(rows: List[Dict], csv_path=None) -> None:
    _collected.extend(rows)
    lines = []
    for r in rows:
        for k, v in r.items():
            if k == "name":
                continue
            lines.append(f"{r['name']},{k},{v}")
    text = "\n".join(lines)
    print(text)
    if csv_path:
        with open(csv_path, "a") as f:
            f.write(text + "\n")
