"""Coordinator hot-path microbenchmark (boundary / classification / codec).

Measures the three costs the O(delta) refactor targets, so regressions show
up as numbers rather than folklore:

* ``boundary_*``  — per-report ingest + poll round cost, incremental
  maintenance vs. the retained from-scratch fixpoint oracle, across member
  counts and a 10x persisted-history multiplier. The incremental rounds
  must stay flat as history grows; the oracle scales with graph size.
* ``poll_idle_*`` — steady-state poll latency when nothing moved:
  seq-gated delta polls (ship ``None``) vs. force-shipping the boundary
  dict every 2 ms like the seed did.
* ``classify_*``  — message classification against 50 accumulated rollback
  decisions: compacted DecisionIndex vs. the linear decision-list scan.
* ``codec_*``     — wire bytes + round-trip time, binary codec vs. the
  legacy JSON encoding, for headers / metadata / report batches.
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

from repro.core.coordinator import Coordinator
from repro.core.ids import (
    DecisionIndex,
    Header,
    PersistReport,
    RollbackDecision,
    Vertex,
    encode_metadata,
    encode_metadata_json,
    encode_reports,
    decode_metadata,
    decode_reports,
    vertex_rolled_back,
)

from .common import emit


# ------------------------------------------------------------------ #
# boundary advance                                                   #
# ------------------------------------------------------------------ #
def _drive_rounds(coord: Coordinator, ids, rounds: int, oracle: bool, start: int = 1) -> float:
    """Chain workload: member i's version r depends on member i-1's version
    r (satisfied in report order, so every report advances the boundary).
    Every member polls once per round — the runtime's Refresh cadence.
    Returns mean microseconds per (report + poll)."""
    t0 = time.perf_counter()
    for r in range(start, start + rounds):
        for i, so in enumerate(ids):
            deps = (Vertex(ids[i - 1], 0, r),) if i else ()
            coord.report(so, [PersistReport(Vertex(so, 0, r), deps)])
            if oracle:
                # what every dirty poll cost before incremental maintenance
                coord._graph.recoverable_boundary()
            coord.poll(so, 0)
    wall = time.perf_counter() - t0
    return wall / (rounds * len(ids)) * 1e6


def _bench_boundary(root: Path, quick: bool):
    rows = []
    n_members = 32 if quick else 128
    base_rounds = 8 if quick else 20
    ids = [f"so{i:03d}" for i in range(n_members)]

    for label, rounds, oracle in (
        ("boundary_inc_h1", base_rounds, False),
        ("boundary_inc_h10", base_rounds * 10, False),
        ("boundary_oracle_h1", base_rounds, True),
    ):
        coord = Coordinator(root / f"{label}.jsonl")
        for so in ids:
            coord.connect(so, [])
        _drive_rounds(coord, ids, 3, oracle)  # warmup: exclude first-touch costs
        us = _drive_rounds(coord, ids, rounds, oracle, start=4)
        coord.close()
        rows.append({"name": label, "us_per_round": round(us, 2)})
    return rows


def _bench_poll_idle(root: Path, quick: bool):
    rows = []
    for n_members in (20, 200):
        ids = [f"so{i:03d}" for i in range(n_members)]
        coord = Coordinator(root / f"poll{n_members}.jsonl")
        for so in ids:
            coord.connect(so, [])
            coord.report(so, [PersistReport(Vertex(so, 0, 1), ())])
        resp = coord.poll(ids[0], 0)  # settle the cache
        seq = resp.boundary_seq
        n = 2000 if quick else 20000
        t0 = time.perf_counter()
        for k in range(n):
            coord.poll(ids[k % n_members], 0, seq)  # gated: nothing moved
        gated = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for k in range(n):
            coord.poll(ids[k % n_members], 0, -1)  # seed behaviour: full dict
        full = (time.perf_counter() - t0) / n * 1e6
        coord.close()
        rows.append(
            {
                "name": f"poll_idle_m{n_members}",
                "gated_us": round(gated, 3),
                "full_us": round(full, 3),
            }
        )
    return rows


# ------------------------------------------------------------------ #
# decision compaction                                                #
# ------------------------------------------------------------------ #
def _bench_classify(quick: bool):
    n_decisions = 50
    n_sos = 20
    ids = [f"so{i:02d}" for i in range(n_sos)]
    decisions = [
        RollbackDecision(
            fsn=f,
            failed=ids[f % n_sos],
            targets={ids[(f + j) % n_sos]: 10 * f + j for j in range(5)},
        )
        for f in range(1, n_decisions + 1)
    ]
    index = DecisionIndex(decisions)
    # header-shaped probe set: worlds spread across the fsn range so both
    # paths exercise early-out and full-scan cases
    probes = [
        Vertex(ids[k % n_sos], (k * 7) % (n_decisions + 2), (k * 13) % 600)
        for k in range(256)
    ]
    # equivalence guard: a benchmark comparing two different answers is void
    for v in probes:
        assert index.invalidates(v) == vertex_rolled_back(v, decisions)

    n = 20 if quick else 200
    t0 = time.perf_counter()
    for _ in range(n):
        for v in probes:
            vertex_rolled_back(v, decisions)
    linear = (time.perf_counter() - t0) / (n * len(probes)) * 1e6
    t0 = time.perf_counter()
    for _ in range(n):
        for v in probes:
            index.invalidates(v)
    indexed = (time.perf_counter() - t0) / (n * len(probes)) * 1e6
    return [
        {
            "name": f"classify_d{n_decisions}",
            "linear_us": round(linear, 4),
            "indexed_us": round(indexed, 4),
            "speedup": round(linear / indexed, 2),
        }
    ]


# ------------------------------------------------------------------ #
# wire codec                                                         #
# ------------------------------------------------------------------ #
def _bench_codec(quick: bool):
    rows = []
    header = Header.of(*(Vertex(f"service-{i}", 0, 40 + i) for i in range(3)))
    legacy_header = json.dumps(sorted(v.to_json() for v in header.deps)).encode()
    deps = [Vertex(f"service-{i % 4}", 0, i) for i in range(5)]
    user = bytes(range(64))
    reports = [
        PersistReport(
            Vertex("service-a", 0, v), (Vertex("service-b", 0, v), Vertex("service-c", 0, v))
        )
        for v in range(20)
    ]
    legacy_reports = json.dumps([r.to_json() for r in reports]).encode()

    n = 2000 if quick else 20000
    t0 = time.perf_counter()
    for _ in range(n):
        Header.decode(header.encode())
        decode_metadata(encode_metadata(3, 9, deps, user))
        decode_reports(encode_reports(reports))
    rt = (time.perf_counter() - t0) / n * 1e6
    rows.append(
        {
            "name": "codec",
            "roundtrip_us": round(rt, 3),
            "header_bytes": len(header.encode()),
            "header_bytes_json": len(legacy_header),
            "metadata_bytes": len(encode_metadata(3, 9, deps, user)),
            "metadata_bytes_json": len(encode_metadata_json(3, 9, deps, user)),
            "reports20_bytes": len(encode_reports(reports)),
            "reports20_bytes_json": len(legacy_reports),
        }
    )
    return rows


def run(quick: bool = True, csv_path=None) -> None:
    rows = []
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        rows += _bench_boundary(root, quick)
        rows += _bench_poll_idle(root, quick)
    rows += _bench_classify(quick)
    rows += _bench_codec(quick)
    emit(rows, csv_path)


if __name__ == "__main__":
    run(quick=True)
