"""DSE vs durable-execution baseline: the paper's evaluation shape (§6.1,
Figs. 9/11 generalized) — per-op latency (median/p99) and throughput for the
speculative DSERuntime against the synchronous DurableRuntime, across
services (counter / kv / workflow) and simulated persistence latencies
(0 / 1 / 5 ms).

The baseline pays a synchronous persist + coordinator-report round-trip
before every externally-visible effect (what Temporal/Beldi/Boki-class
engines charge per transition); DSE acknowledges speculatively and hides
persistence behind the group commit + barrier. The headline claim this
reproduces: DSE median latency is several times below the durable baseline
already at 1 ms persistence latency, and the gap widens with it.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_eval [--full] [--json out.json]
    PYTHONPATH=src python -m benchmarks.run --only eval --json out.json

``--json`` writes the ``{"eval": {"row.metric": value}}`` shape that
``benchmarks/compare.py`` diffs against the committed ``BENCH_PR4.json``
baseline (the CI ``differential-sweep`` job uploads the diff as an
artifact). ``speedup_p50`` rows (durable_p50 / dse_p50) are the guarded
metrics: compare.py fails a speedup only when it *drops* below
baseline/threshold, so runner noise on microsecond DSE latencies cannot
flake the gate.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from repro.core import LocalCluster

from .common import emit, pctl, timer

GC = 0.010  # paper's 10 ms group commit
IO_SWEEP_MS = (0.0, 1.0, 5.0)


def _counter_cell(root: Path, runtime: str, io_ms: float, n_ops: int):
    from repro.services.counter import CounterStateObject

    with LocalCluster(root, group_commit_interval=GC, runtime=runtime) as cluster:
        ctr = cluster.add("ctr", lambda: CounterStateObject(root / "so", io_ms=io_ms))
        lat: list = []
        t0 = time.perf_counter()
        for _ in range(n_ops):
            with timer(lat):
                out = ctr.increment(None)
                assert out is not None
        dt = time.perf_counter() - t0
    return lat, n_ops / dt


def _kv_cell(root: Path, runtime: str, io_ms: float, n_ops: int):
    from repro.services.kv_store import SpeculativeKVStore

    with LocalCluster(root, group_commit_interval=GC, runtime=runtime) as cluster:
        kv = cluster.add("kv", lambda: SpeculativeKVStore(root / "so", io_ms=io_ms))
        lat: list = []
        t0 = time.perf_counter()
        for i in range(n_ops):
            with timer(lat):
                out = kv.put(f"k{i % 50}", f"v{i}")
                assert out is not None
        dt = time.perf_counter() - t0
    return lat, n_ops / dt


def _workflow_cell(root: Path, runtime: str, io_ms: float, n_ops: int, n_steps: int = 3):
    from repro.services.kv_store import SpeculativeKVStore
    from repro.services.workflow import WorkflowEngine

    with LocalCluster(root, group_commit_interval=GC, runtime=runtime) as cluster:
        kv = cluster.add("kv", lambda: SpeculativeKVStore(root / "so_kv", io_ms=io_ms))
        kv.stock("item", 10**9)
        wf = cluster.add("wf", lambda: WorkflowEngine(root / "so_wf", io_ms=io_ms))
        lat: list = []
        t0 = time.perf_counter()
        for i in range(n_ops):
            wf_id = f"wf{i}"
            steps = [
                (lambda h, w=wf_id, s=s: kv.try_reserve("item", f"{w}:{s}", h))
                for s in range(n_steps)
            ]
            with timer(lat):
                out = wf.run_workflow(wf_id, steps)
                assert out is not None
        dt = time.perf_counter() - t0
    return lat, n_ops / dt


CELLS = {
    "counter": _counter_cell,
    "kv": _kv_cell,
    "workflow": _workflow_cell,
}


def run(quick: bool = True, csv_path=None):
    n_ops = {"counter": 120, "kv": 120, "workflow": 15}
    if not quick:
        n_ops = {k: v * 4 for k, v in n_ops.items()}
    rows = []
    for service, cell in CELLS.items():
        for io_ms in IO_SWEEP_MS:
            stats = {}
            for runtime in ("dse", "durable"):
                with tempfile.TemporaryDirectory() as td:
                    lat, ops_s = cell(Path(td), runtime, io_ms, n_ops[service])
                stats[runtime] = {
                    "p50_ms": pctl(lat, 50),
                    "p99_ms": pctl(lat, 99),
                    "ops_per_s": round(ops_s, 1),
                }
            row = {"name": f"eval/{service}/io{io_ms:g}ms"}
            for runtime, st in stats.items():
                for k, v in st.items():
                    row[f"{runtime}_{k}"] = round(v, 4) if isinstance(v, float) else v
            row["speedup_p50"] = round(
                stats["durable"]["p50_ms"] / max(stats["dse"]["p50_ms"], 1e-9), 2
            )
            rows.append(row)
    emit(rows, csv_path)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="4x more ops per cell")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--json", default=None, help="write {'eval': {row.metric: value}}")
    args = ap.parse_args()
    rows = run(quick=not args.full, csv_path=args.csv)
    if args.json:
        payload = {
            "eval": {
                f"{r['name']}.{k}": v for r in rows for k, v in r.items() if k != "name"
            }
        }
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {args.json}")
    # the paper's headline, as a visible pass/fail line (not an exception:
    # benchmarks report, CI artifacts diff — tests assert)
    for r in rows:
        if r["name"].endswith("io1ms"):
            verdict = "OK" if r["speedup_p50"] >= 3.0 else "BELOW 3x"
            print(
                f"{r['name']}: DSE p50 {r['dse_p50_ms']:.3f} ms vs durable "
                f"{r['durable_p50_ms']:.3f} ms -> {r['speedup_p50']}x [{verdict}]"
            )


if __name__ == "__main__":
    main()
