"""Recovery behaviour (paper Figs. 12/13): kill-and-restart during the
event-processing pipeline (throughput timeline around the failure) and a
2PC worker fail-over (how many transactions abort under speculation vs
baseline — speculation aggressively rolls back more, paper §6.2).

PR 5 adds the restart-latency-vs-history-length suite (DESIGN.md §11):
coordinator restart + runtime reconnect cost as a function of accumulated
failure history, with snapshot compaction on vs off. The acceptance bar:
with snapshots, recovery latency stays flat across a 10x history increase
and beats no-snapshot recovery >= 5x at the largest point.

Standalone (the CI gate runs this against the committed BENCH_PR5.json):
    PYTHONPATH=src python -m benchmarks.bench_recovery --restart-only \
        --json bench-recovery.json
"""
from __future__ import annotations

import json as _json
import tempfile
import time
from pathlib import Path

from repro.core import DelayMessage, LocalCluster
from repro.services import (
    EventBroker,
    TwoPCClient,
    TwoPCCoordinator,
    TwoPCParticipant,
)

from .common import emit


def event_recovery(root: Path, kill_after: int, n_events: int):
    cluster = LocalCluster(root, group_commit_interval=0.01)
    mk = lambda: EventBroker(root / "br", topics=["t0"])
    br = cluster.add("broker", mk)
    done = 0
    timeline = []  # (t_ms, events_done)
    t_start = time.perf_counter()
    killed = False
    recovered_at = None
    try:
        while done < n_events:
            if not killed and done >= kill_after:
                t0 = time.perf_counter()
                br = cluster.kill("broker")  # restart + rollback recovery
                recovered_at = (time.perf_counter() - t0) * 1e3
                killed = True
            try:
                out = br.produce("t0", [f"e{done}".encode()])
                if out is None:
                    continue
                _, h = out
                got = br.consume("g", "t0", header=h)
                if got is None:
                    continue
                evs, h2 = got
                if evs:
                    br.ack("g", "t0", evs[-1][0], header=h2)
            except DelayMessage:
                cluster.refresh_all()
                continue
            done += 1
            timeline.append(((time.perf_counter() - t_start) * 1e3, done))
    finally:
        cluster.shutdown()
    return recovered_at, timeline


def twopc_failover(root: Path, speculative: bool, n_txns: int, kill_at: int):
    cluster = LocalCluster(root, group_commit_interval=0.01)
    parts = [
        cluster.add(
            f"p{i}",
            (lambda i=i: TwoPCParticipant(root / f"p{i}", speculative=speculative)),
        )
        for i in range(4)
    ]
    coord = cluster.add(
        "coord", lambda: TwoPCCoordinator(root / "coord", speculative=speculative)
    )
    aborted = committed = retries = 0
    try:
        client = TwoPCClient(coord, parts)
        for i in range(n_txns):
            if i == kill_at:
                # fail p0 BETWEEN txn-start and commit: its (speculative)
                # start record is lost => it votes no => abort. This is the
                # paper's §6.2 abort mechanism.
                for p in parts:
                    p.txn_start(f"t{i}")
                cluster.kill("p0")
                parts[0] = cluster.get("p0")
                client = TwoPCClient(coord, parts)
                cluster.refresh_all()
                out = None
                for _ in range(10):
                    try:
                        out = coord.commit_txn(f"t{i}", parts)
                        break
                    except DelayMessage:
                        cluster.refresh_all()
                        retries += 1
                if out is not None and out[0] is False:
                    aborted += 1
                elif out is not None:
                    committed += 1
                continue
            # closed-loop client with retry (discarded cross-epoch messages
            # surface as None => retry after a refresh)
            for attempt in range(10):
                try:
                    ok = client.run(f"t{i}")
                except DelayMessage:
                    cluster.refresh_all()
                    retries += 1
                    continue
                if ok is None:
                    cluster.refresh_all()
                    retries += 1
                    continue
                if ok:
                    committed += 1
                else:
                    aborted += 1
                break
    finally:
        cluster.shutdown()
    return committed, aborted, retries


def _times(n: int, fn) -> list:
    """Wall-clock ms of ``fn()`` over ``n`` trials."""
    out = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        out.append((time.perf_counter() - t0) * 1e3)
    return out


def _settle_boundary(cluster, timeout=30.0) -> None:
    """Drive refresh rounds until the coordinator serves a boundary again
    (fragment resends + boundary fixpoint after a restart)."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        cluster.refresh_all()
        if cluster.coordinator.current_boundary() is not None:
            return
    raise TimeoutError("coordinator never recovered a boundary")


def restart_vs_history(root: Path, n_hist: int, with_snapshots: bool):
    """(restart_ms, reconnect_ms) after ``n_hist`` accumulated rollback
    decisions, with snapshot compaction on or off.

    The failure history is synthesized by appending inert decision records
    straight to the WAL (targets above every live watermark => skip-path
    no-ops when applied; lost windows already passed => the first
    checkpoint retires them) — generating 10^3..10^4 REAL kill/restart
    cycles would cost minutes of fsyncs and measure the same replay path.
    Both sides then pay one setup restart to absorb the history; the timed
    restart after that is pure recovery: with snapshots it replays the
    compacted snapshot + empty suffix, without it the full decision log.
    """
    from repro.services.counter import CounterStateObject

    cluster = LocalCluster(
        root,
        group_commit_interval=0.005,
        refresh_interval=None,
        checkpoint_records=(256 if with_snapshots else None),
    )
    try:
        a = cluster.add("a", lambda: CounterStateObject(root / "so_a"))
        b = cluster.add("b", lambda: CounterStateObject(root / "so_b"))
        for _ in range(20):  # live traffic: fragments + an exposure floor
            out = a.increment(None)
            if out is not None:
                b.increment(out[1])
            a.runtime.maybe_persist(force=True)
            b.runtime.maybe_persist(force=True)
        _settle_boundary(cluster)

        # synthetic failure history, buffered append (see docstring)
        log = cluster.coordinator._log
        wal = log._wal_path(log.generation)
        base_fsn = int(cluster.coordinator.stats()["fsn"])
        with open(wal, "a") as f:
            for i in range(n_hist):
                f.write(
                    _json.dumps(
                        {
                            "type": "decision",
                            "fsn": base_fsn + 1 + i,
                            "failed": "a",
                            "targets": {"a": 10**6, "b": 10**6},
                            "lost": {"a": 0, "b": 0},
                        }
                    )
                    + "\n"
                )
        # setup restart absorbs the history (both sides pay this equally);
        # runtimes apply the decisions and advance to the final world
        cluster.restart_coordinator()
        _settle_boundary(cluster)
        if with_snapshots:
            cluster.checkpoint()  # auto-trigger would fire too; be explicit

        def one_restart():
            cluster.restart_coordinator()  # durable-store replay is here
            _settle_boundary(cluster)  # ...then resends + boundary fixpoint

        # min over a few trials: recovery is deterministic compute + a
        # settle round-trip, so the min is the clean measure and the gate
        # stays robust to CI-runner scheduling noise
        restart_ms = min(_times(3, one_restart))
        # reconnect: ConnectResponse ships (and the runtime re-indexes) the
        # retained decision set; each trial adds one real decision, which
        # perturbs n_hist by a rounding error
        reconnect_ms = min(_times(3, lambda: cluster.kill("a")))
        return restart_ms, reconnect_ms
    finally:
        cluster.shutdown()


def run_restart_suite(quick: bool = True):
    h = 200 if quick else 1000
    sizes = (h, 10 * h)
    rows = []
    results = {}
    for n_hist in sizes:
        for snap in (False, True):
            with tempfile.TemporaryDirectory() as td:
                results[(n_hist, snap)] = restart_vs_history(Path(td), n_hist, snap)
    # Gated metrics (compare.py names): no_snap_ms — hundreds of ms of
    # CPU-bound replay, load-robust — and snapshot_speedup, clamped at 50x
    # (past that the denominator is low-single-digit ms of fsync/settle
    # noise and the raw ratio flaps); with the CI threshold of 10 the
    # clamped baseline puts the gate's floor at 50/10 = 5x — exactly the
    # acceptance bar ("snapshot recovery >= 5x faster at the largest
    # history point"). The with-snapshot absolute times are emitted as
    # *_ms_info (ms values, deliberately outside compare.py's gated-name
    # patterns): single-digit-ms wall times triple under shared-runner
    # load, and the bound they witness is already gated via the speedup.
    clamp = lambda num, den: round(min(num / max(den, 1e-9), 50.0), 2)
    for n_hist in sizes:
        no_restart, no_reconn = results[(n_hist, False)]
        yes_restart, yes_reconn = results[(n_hist, True)]
        rows.append({
            "name": f"recovery/restart/h{n_hist}",
            "no_snap_ms": round(no_restart, 2),
            "with_snap_ms_info": round(yes_restart, 2),
            "snapshot_speedup": clamp(no_restart, yes_restart),
        })
        rows.append({
            "name": f"recovery/reconnect/h{n_hist}",
            "no_snap_ms": round(no_reconn, 2),
            "with_snap_ms_info": round(yes_reconn, 2),
            "snapshot_speedup": clamp(no_reconn, yes_reconn),
        })
    # flatness: snapshot-recovery latency must not scale with history
    # (ratio ~1.0; not a gated metric name — restart latency has a floor of
    # one refresh round, so the gate rides the speedups above instead)
    rows.append({
        "name": "recovery/restart",
        "snap_flat_x": round(
            results[(sizes[1], True)][0] / max(results[(sizes[0], True)][0], 1e-9), 2
        ),
        "no_snap_growth_x": round(
            results[(sizes[1], False)][0] / max(results[(sizes[0], False)][0], 1e-9), 2
        ),
    })
    return rows


def run(quick: bool = True, csv_path=None):
    rows = []
    with tempfile.TemporaryDirectory() as td:
        rec_ms, timeline = event_recovery(Path(td), kill_after=20, n_events=60)
        rows.append({
            "name": "recovery/event",
            "restart_plus_rollback_ms": round(rec_ms, 1),
            "events_completed": timeline[-1][1],
        })
    n = 40 if quick else 200
    for spec in (True, False):
        with tempfile.TemporaryDirectory() as td:
            c, a, e = twopc_failover(Path(td), spec, n, kill_at=n // 2)
            tag = "dse" if spec else "baseline"
            rows.append({
                "name": f"recovery/2pc/{tag}",
                "committed": c, "aborted": a, "client_retries": e,
            })
    rows += run_restart_suite(quick)
    emit(rows, csv_path)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--restart-only", action="store_true",
                    help="run only the restart-vs-history suite (the CI gate)")
    ap.add_argument("--json", default=None,
                    help="write {'recovery': {row.metric: value}} for compare.py")
    args = ap.parse_args()
    if args.restart_only:
        rows = run_restart_suite(quick=not args.full)
        emit(rows)
    else:
        rows = run(quick=not args.full)
    if args.json:
        payload = {
            "recovery": {
                f"{r['name']}.{k}": v
                for r in rows
                for k, v in r.items()
                if k != "name"
            }
        }
        Path(args.json).write_text(_json.dumps(payload, indent=2, sort_keys=True) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
