"""Recovery behaviour (paper Figs. 12/13): kill-and-restart during the
event-processing pipeline (throughput timeline around the failure) and a
2PC worker fail-over (how many transactions abort under speculation vs
baseline — speculation aggressively rolls back more, paper §6.2).
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import DelayMessage, LocalCluster
from repro.services import (
    EventBroker,
    TwoPCClient,
    TwoPCCoordinator,
    TwoPCParticipant,
)

from .common import emit


def event_recovery(root: Path, kill_after: int, n_events: int):
    cluster = LocalCluster(root, group_commit_interval=0.01)
    mk = lambda: EventBroker(root / "br", topics=["t0"])
    br = cluster.add("broker", mk)
    done = 0
    timeline = []  # (t_ms, events_done)
    t_start = time.perf_counter()
    killed = False
    recovered_at = None
    try:
        while done < n_events:
            if not killed and done >= kill_after:
                t0 = time.perf_counter()
                br = cluster.kill("broker")  # restart + rollback recovery
                recovered_at = (time.perf_counter() - t0) * 1e3
                killed = True
            try:
                out = br.produce("t0", [f"e{done}".encode()])
                if out is None:
                    continue
                _, h = out
                got = br.consume("g", "t0", header=h)
                if got is None:
                    continue
                evs, h2 = got
                if evs:
                    br.ack("g", "t0", evs[-1][0], header=h2)
            except DelayMessage:
                cluster.refresh_all()
                continue
            done += 1
            timeline.append(((time.perf_counter() - t_start) * 1e3, done))
    finally:
        cluster.shutdown()
    return recovered_at, timeline


def twopc_failover(root: Path, speculative: bool, n_txns: int, kill_at: int):
    cluster = LocalCluster(root, group_commit_interval=0.01)
    parts = [
        cluster.add(
            f"p{i}",
            (lambda i=i: TwoPCParticipant(root / f"p{i}", speculative=speculative)),
        )
        for i in range(4)
    ]
    coord = cluster.add(
        "coord", lambda: TwoPCCoordinator(root / "coord", speculative=speculative)
    )
    aborted = committed = retries = 0
    try:
        client = TwoPCClient(coord, parts)
        for i in range(n_txns):
            if i == kill_at:
                # fail p0 BETWEEN txn-start and commit: its (speculative)
                # start record is lost => it votes no => abort. This is the
                # paper's §6.2 abort mechanism.
                for p in parts:
                    p.txn_start(f"t{i}")
                cluster.kill("p0")
                parts[0] = cluster.get("p0")
                client = TwoPCClient(coord, parts)
                cluster.refresh_all()
                out = None
                for _ in range(10):
                    try:
                        out = coord.commit_txn(f"t{i}", parts)
                        break
                    except DelayMessage:
                        cluster.refresh_all()
                        retries += 1
                if out is not None and out[0] is False:
                    aborted += 1
                elif out is not None:
                    committed += 1
                continue
            # closed-loop client with retry (discarded cross-epoch messages
            # surface as None => retry after a refresh)
            for attempt in range(10):
                try:
                    ok = client.run(f"t{i}")
                except DelayMessage:
                    cluster.refresh_all()
                    retries += 1
                    continue
                if ok is None:
                    cluster.refresh_all()
                    retries += 1
                    continue
                if ok:
                    committed += 1
                else:
                    aborted += 1
                break
    finally:
        cluster.shutdown()
    return committed, aborted, retries


def run(quick: bool = True, csv_path=None):
    rows = []
    with tempfile.TemporaryDirectory() as td:
        rec_ms, timeline = event_recovery(Path(td), kill_after=20, n_events=60)
        rows.append({
            "name": "recovery/event",
            "restart_plus_rollback_ms": round(rec_ms, 1),
            "events_completed": timeline[-1][1],
        })
    n = 40 if quick else 200
    for spec in (True, False):
        with tempfile.TemporaryDirectory() as td:
            c, a, e = twopc_failover(Path(td), spec, n, kill_at=n // 2)
            tag = "dse" if spec else "baseline"
            rows.append({
                "name": f"recovery/2pc/{tag}",
                "committed": c, "aborted": a, "client_retries": e,
            })
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
