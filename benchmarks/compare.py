"""Perf-smoke comparator: diff a fresh ``benchmarks.run --json`` output
against a committed baseline (BENCH_PR<N>.json) and fail on regressions.

Usage:
    python -m benchmarks.compare --baseline BENCH_PR3.json \
        --current out.json [--suite coordinator] [--threshold 3.0]

Only *time-like* metrics (``*_us``, ``*_ms``, ``us_per_*``, ``*_s``) are
thresholded — a current value more than ``threshold`` times the baseline
fails. ``*speedup*`` metrics fail when they drop below baseline/threshold.
The threshold is deliberately wide: CI runners are noisy, and this step
exists to catch order-of-magnitude algorithmic regressions (an O(delta)
path quietly going O(history)), not 20% wobbles. Metrics present in only
one file are reported but never fail the run.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _is_time_metric(name: str) -> bool:
    metric = name.rsplit(".", 1)[-1]
    return (
        metric.endswith("_us")
        or metric.endswith("_ms")
        or metric.endswith("_s")
        or metric.startswith("us_per")
        or metric.startswith("ms_per")
    )


def _is_speedup_metric(name: str) -> bool:
    return "speedup" in name.rsplit(".", 1)[-1]


def compare(baseline: dict, current: dict, suites, threshold: float):
    failures, checked = [], 0
    for suite, base_metrics in sorted(baseline.items()):
        if suites and suite not in suites:
            continue
        cur_metrics = current.get(suite, {})
        for name, base_val in sorted(base_metrics.items()):
            cur_val = cur_metrics.get(name)
            if cur_val is None or not isinstance(base_val, (int, float)):
                continue
            if _is_time_metric(name) and base_val > 0:
                checked += 1
                ratio = cur_val / base_val
                line = f"{suite}.{name}: {base_val} -> {cur_val} ({ratio:.2f}x)"
                if ratio > threshold:
                    failures.append(line)
                    print(f"FAIL {line}")
                else:
                    print(f"  ok {line}")
            elif _is_speedup_metric(name) and base_val > 0:
                checked += 1
                line = f"{suite}.{name}: {base_val} -> {cur_val}"
                if cur_val < base_val / threshold:
                    failures.append(line)
                    print(f"FAIL {line} (below {base_val / threshold:.2f})")
                else:
                    print(f"  ok {line}")
    return failures, checked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--suite", action="append", default=None,
                    help="restrict to suite(s); default: all in baseline")
    ap.add_argument("--threshold", type=float, default=3.0)
    args = ap.parse_args()

    baseline = json.loads(Path(args.baseline).read_text())
    current = json.loads(Path(args.current).read_text())
    failures, checked = compare(baseline, current, args.suite, args.threshold)
    print(f"checked {checked} metrics, {len(failures)} regression(s)")
    if checked == 0:
        # A gate that matched nothing is a broken gate, not a green one —
        # suite/metric renames must update the committed baseline too.
        print("ERROR: no metrics matched between baseline and current")
        sys.exit(1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
