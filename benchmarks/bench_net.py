"""Transport fabric + sharded coordinator microbenchmark (repro.net).

Compares service-call throughput for:

* ``direct``        — LocalCluster, in-process calls (the seed's transport)
* ``net-shard<N>``  — NetCluster over SimTransport with batched delivery and
                      an N-shard coordinator (N in {1, 2, 4})

Concurrent clients drive round-robin increments across K counter SOs, so
messages queue and the fabric's batch coalescing is visible (mean_batch).
Reported per config: ops/s, mean delivered batch size, wire bytes/op.
"""
from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

from repro.core import LocalCluster
from repro.net import LinkSpec, NetCluster, SimTransport
from repro.services.counter import CounterStateObject

from .common import emit


def _drive(cluster, so_ids, total_ops: int, threads: int, via_transport: bool) -> float:
    """Round-robin increments from concurrent clients; returns wall seconds."""
    errs = []

    def worker(tid: int, n_ops: int) -> None:
        try:
            for i in range(n_ops):
                so_id = so_ids[(tid + i) % len(so_ids)]
                if via_transport:
                    cluster.send(None, so_id, "increment", None)
                else:
                    cluster.get(so_id).increment(None)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    per = total_ops // threads
    ts = [threading.Thread(target=worker, args=(t, per)) for t in range(threads)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return wall


def run(quick: bool = True, csv_path=None) -> None:
    total_ops = 240 if quick else 2400
    threads = 8
    n_sos = 4
    rows = []

    with tempfile.TemporaryDirectory() as td:
        root = Path(td)

        # -- direct (seed transport) ------------------------------------- #
        c = LocalCluster(root / "direct", group_commit_interval=0.01)
        ids = [f"so{i}" for i in range(n_sos)]
        for so_id in ids:
            c.add(so_id, (lambda p: (lambda: CounterStateObject(p)))(root / f"d_{so_id}"))
        wall = _drive(c, ids, total_ops, threads, via_transport=False)
        c.shutdown()
        rows.append({"name": "net_direct", "ops_per_s": total_ops / wall})

        # -- transport-batched, sharded coordinator ----------------------- #
        for shards in (1, 2, 4):
            tr = SimTransport(
                seed=0,
                default_link=LinkSpec(latency_ms=0.2, jitter_ms=0.1),
                batch_size=64,
                retry_timeout=0.05,
            )
            c = NetCluster(
                root / f"net{shards}",
                transport=tr,
                n_shards=shards,
                group_commit_interval=0.01,
            )
            for so_id in ids:
                c.add(
                    so_id,
                    (lambda p: (lambda: CounterStateObject(p)))(root / f"n{shards}_{so_id}"),
                )
            wall = _drive(c, ids, total_ops, threads, via_transport=True)
            st = c.transport.stats()
            c.shutdown()
            rows.append(
                {
                    "name": f"net_shard{shards}",
                    "ops_per_s": total_ops / wall,
                    "mean_batch": round(st["mean_batch"], 2),
                    "wire_bytes_per_op": round(st["bytes"] / total_ops, 1),
                    "retries": st["retries"],
                }
            )

    emit(rows, csv_path)


if __name__ == "__main__":
    run(quick=True)
