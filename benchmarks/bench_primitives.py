"""Primitive thread-scalability (paper Fig. 15): throughput of local-action,
send-receive, and detach-merge under concurrent threads while a background
thread performs empty checkpoints to advance versions.

CPython/GIL + 1-core caveat recorded in EXPERIMENTS.md: absolute numbers are
bounded by the interpreter; the claim preserved is that the epoch-protected
action path adds no *coordination collapse* as threads increase (the biased
reader fast path touches only its stripe).
"""
from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

from repro.core import LocalCluster
from repro.services.counter import CounterStateObject as CounterSO

from .common import emit


def _throughput(so, mode: str, n_threads: int, dur_s: float = 0.5):
    stop = threading.Event()
    counts = [0] * n_threads

    def worker(idx: int):
        hdr = None
        while not stop.is_set():
            if mode == "local-action":
                if so.StartAction(None):
                    so.EndAction()
            elif mode == "send-receive":
                if so.StartAction(hdr):
                    hdr = so.EndAction()
            else:  # detach-merge
                if so.StartAction(hdr):
                    t = so.Detach()
                    if so.Merge(t):
                        hdr = so.EndAction()
            counts[idx] += 1

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n_threads)]
    # background checkpointer advancing versions (paper's setup)
    def checkpointer():
        while not stop.is_set():
            so.runtime.maybe_persist(force=True)
            time.sleep(0.005)

    ck = threading.Thread(target=checkpointer)
    for t in threads:
        t.start()
    ck.start()
    time.sleep(dur_s)
    stop.set()
    for t in threads:
        t.join()
    ck.join()
    return sum(counts) / dur_s


def run(quick: bool = True, csv_path=None):
    rows = []
    for mode in ("local-action", "send-receive", "detach-merge"):
        for n_threads in (1, 2, 4):
            with tempfile.TemporaryDirectory() as td:
                cluster = LocalCluster(Path(td), group_commit_interval=99,
                                       refresh_interval=None)
                so = cluster.add("so", lambda: CounterSO(Path(td) / "so"))
                try:
                    thr = _throughput(so, mode, n_threads)
                    rows.append({
                        "name": f"primitives/{mode}/threads={n_threads}",
                        "ops_per_s": round(thr),
                    })
                finally:
                    cluster.shutdown()
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
