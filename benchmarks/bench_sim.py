"""Simulation-scheduler overhead (beyond-paper: repro.sim, DESIGN.md §8).

The deterministic scheduler context-switches real OS threads one at a time,
so its hand-off cost bounds how many fault scenarios a sweep can afford.
Reported as simulated **events/sec** on three workloads:

* ``sched_pingpong`` — two tasks alternating through a SimEvent: pure
  hand-off cost, no time advance;
* ``sched_sleepstorm`` — many tasks sleeping staggered virtual durations:
  time-jump (deadline heap) throughput, plus the virtual-seconds-per-
  wall-second speedup that makes 60-virtual-second tests run in wall
  milliseconds;
* ``sim_full_stack`` — the explore ``counter`` scenario end-to-end
  (transport, sharded coordinator, crashes, invariant checks): what a
  seed-sweep actually pays per seed.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from .common import emit


def _pingpong(rounds: int):
    from repro.sim import SimScheduler

    sched = SimScheduler(seed=0)

    def main():
        ping = sched.clock.event()
        pong = sched.clock.event()

        def partner():
            for _ in range(rounds):
                ping.wait()
                ping.clear()
                pong.set()

        sched.clock.spawn(partner, name="partner")
        for _ in range(rounds):
            ping.set()
            pong.wait()
            pong.clear()

    t0 = time.perf_counter()
    sched.run(main)
    dt = time.perf_counter() - t0
    return {
        "name": "sched_pingpong",
        "rounds": rounds,
        "events": sched.events,
        "events_per_s": round(sched.events / dt),
        "wall_s": round(dt, 3),
    }


def _sleepstorm(n_tasks: int, n_sleeps: int):
    from repro.sim import SimScheduler

    sched = SimScheduler(seed=0)

    def main():
        def sleeper(i: int):
            for j in range(n_sleeps):
                sched.clock.sleep(0.1 + (i * 7 + j) % 13 * 0.01)

        tasks = [
            sched.clock.spawn(lambda i=i: sleeper(i), name=f"s{i}")
            for i in range(n_tasks)
        ]
        for t in tasks:
            t.join()

    t0 = time.perf_counter()
    sched.run(main, max_virtual_time=1e9)
    dt = time.perf_counter() - t0
    return {
        "name": "sched_sleepstorm",
        "tasks": n_tasks,
        "events": sched.events,
        "events_per_s": round(sched.events / dt),
        "virtual_s": round(sched.now, 2),
        "speedup_virtual_per_wall": round(sched.now / dt, 1),
        "wall_s": round(dt, 3),
    }


def _full_stack(n_seeds: int):
    from repro.sim.explore import run_one

    events = 0
    virtual = 0.0
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="bench-sim-") as wd:
        for seed in range(n_seeds):
            r = run_one("counter", seed, Path(wd))
            events += r.events
            virtual += r.virtual_time
    dt = time.perf_counter() - t0
    return {
        "name": "sim_full_stack",
        "seeds": n_seeds,
        "events": events,
        "events_per_s": round(events / dt),
        "seeds_per_s": round(n_seeds / dt, 2),
        "speedup_virtual_per_wall": round(virtual / dt, 2),
        "wall_s": round(dt, 3),
    }


def run(quick: bool = True, csv_path=None) -> None:
    rows = [
        _pingpong(2_000 if quick else 20_000),
        _sleepstorm(20 if quick else 100, 50 if quick else 200),
        _full_stack(2 if quick else 10),
    ]
    emit(rows, csv_path=csv_path)
