"""TwoPhaseCommit (paper Fig. 11): commit latency distribution with one
coordinator + four participants. Baseline latency clusters at multiples of
the group-commit period (sequential synchronous logs); speculative commits
overlap all persists behind one barrier.
"""
from __future__ import annotations

import tempfile
import threading
from pathlib import Path

from repro.core import LocalCluster
from repro.services import TwoPCClient, TwoPCCoordinator, TwoPCParticipant

from .common import emit, pctl, summarize, timer

GC = 0.010
N_PARTICIPANTS = 4


def _run(root: Path, speculative: bool, n_txns: int, n_clients: int = 2):
    cluster = LocalCluster(root, group_commit_interval=GC)
    parts = [
        cluster.add(
            f"p{i}",
            (lambda i=i: TwoPCParticipant(root / f"p{i}", speculative=speculative)),
        )
        for i in range(N_PARTICIPANTS)
    ]
    coord = cluster.add(
        "coord", lambda: TwoPCCoordinator(root / "coord", speculative=speculative)
    )
    lat_ms = []
    lock = threading.Lock()

    def client(cid: int, count: int):
        cl = TwoPCClient(coord, parts)
        mine = []
        for i in range(count):
            with timer(mine):
                ok = cl.run(f"txn{cid}_{i}")
                assert ok is not None
        with lock:
            lat_ms.extend(mine)

    try:
        threads = [
            threading.Thread(target=client, args=(c, n_txns // n_clients))
            for c in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        cluster.shutdown()
    return lat_ms


def run(quick: bool = True, csv_path=None):
    rows = []
    n = 60 if quick else 400
    for spec in (True, False):
        with tempfile.TemporaryDirectory() as td:
            lat = _run(Path(td), spec, n)
            tag = "dse" if spec else "baseline"
            s = summarize(f"2pc/{tag}", lat)
            # paper Fig. 11 observation: fraction finishing under 2 group commits
            s["frac_under_20ms"] = round(
                sum(1 for x in lat if x < 20.0) / max(len(lat), 1), 3
            )
            rows.append(s)
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
