"""Training under DSE (beyond-paper, the TPU-fleet instantiation):

  (a) step latency: synchronous checkpoint-every-step (durable-execution
      baseline) vs DSE speculative steps + async group commit;
  (b) checkpoint bandwidth: full snapshots vs int8 delta codec (the Pallas
      delta_encode kernel), the Fig. 10 storage saving transplanted.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.train import run_resilient_training

from .common import emit


def run(quick: bool = True, csv_path=None):
    rows = []
    cfg = get_config("gemma_2b", smoke=True)
    steps = 10 if quick else 40

    # (a) per-step latency: ONE shared jitted step_fn (warmed up), identical
    # action structure; only the durability wait differs.
    from repro.checkpoint import TrainerStateObject
    from repro.core import LocalCluster
    from repro.data import DataPipelineStateObject, SyntheticLMData
    from repro.launch.steps import make_train_step
    from repro.models import init_params, param_descs
    from repro.optim import AdamWConfig, adamw_init

    data = SyntheticLMData(cfg.vocab_size, 4, 16, seed=0)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), remat="none"))

    def init_state():
        params = init_params(param_descs(cfg), jax.random.key(0), dtype=jax.numpy.float32)
        return params, adamw_init(params)

    def measure(sync_every_step: bool) -> float:
        with tempfile.TemporaryDirectory() as td:
            with LocalCluster(Path(td), group_commit_interval=0.01) as cluster:
                data_so = cluster.add(
                    "data", lambda: DataPipelineStateObject(Path(td) / "d", data)
                )
                trainer = cluster.add(
                    "trainer",
                    lambda: TrainerStateObject(Path(td) / "t", init_state, step_fn),
                )
                per_step = []
                for i in range(steps + 1):
                    t0 = time.perf_counter()
                    s, toks, hdr = data_so.next_batch()
                    trainer.train_on(s, toks, hdr)
                    if sync_every_step:
                        # durable-execution baseline: persist EVERY step
                        assert trainer.StartAction(None)
                        assert trainer.wait_durable(timeout=30.0)
                        trainer.EndAction()
                    if i > 0:  # drop the jit-compile step
                        per_step.append(time.perf_counter() - t0)
                return sum(per_step) / len(per_step)

    sync_s = measure(sync_every_step=True)
    dse_s = measure(sync_every_step=False)
    rows.append({
        "name": "training/step_latency",
        "dse_ms_per_step": round(dse_s * 1e3, 2),
        "sync_ckpt_ms_per_step": round(sync_s * 1e3, 2),
        "speedup": round(sync_s / dse_s, 2),
    })

    # (b) checkpoint bytes: full vs delta codec
    with tempfile.TemporaryDirectory() as td:
        full = run_resilient_training(Path(td) / "f", cfg, steps=steps)
    with tempfile.TemporaryDirectory() as td:
        delta = run_resilient_training(
            Path(td) / "dl", cfg, steps=steps, use_delta_codec=True
        )
    rows.append({
        "name": "training/checkpoint_bytes",
        "full_bytes": full.checkpoint_bytes,
        "delta_bytes": delta.checkpoint_bytes,
        "reduction": round(full.checkpoint_bytes / max(delta.checkpoint_bytes, 1), 2),
    })
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
