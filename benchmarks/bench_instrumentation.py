"""Instrumentation overhead (paper Fig. 14): KV-store op latency/throughput
for (a) no DSE (plain dict behind the same call shape), (b) DSE with manual
header handling, (c) DSE with auto action boundaries (interceptor-style:
headerless actions wrapped per call). The paper finds the protocol itself
costs <5% throughput; the interceptor machinery costs more.
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import LocalCluster, Header
from repro.services import SpeculativeKVStore

from .common import emit, summarize, timer


class PlainKV:
    def __init__(self):
        self._m = {}

    def put(self, k, v, header=None):
        self._m[k] = v
        return None

    def get(self, k, header=None):
        return self._m.get(k), None


def _bench_ops(kv, n_ops: int, with_headers: bool):
    lat = []
    hdr = None
    t0 = time.perf_counter()
    for i in range(n_ops):
        with timer(lat):
            out = kv.put(f"k{i % 256}", "v", hdr if with_headers else None)
            if with_headers and out is not None:
                hdr = out if isinstance(out, Header) else None
            got = kv.get(f"k{i % 256}", hdr if with_headers else None)
            if with_headers and got is not None:
                hdr = got[1]
    dt = time.perf_counter() - t0
    return lat, n_ops * 2 / dt


def run(quick: bool = True, csv_path=None):
    rows = []
    n = 3000 if quick else 20000

    lat, thr = _bench_ops(PlainKV(), n, with_headers=False)
    s = summarize("instr/no_dse", lat)
    s["ops_per_s"] = round(thr)
    rows.append(s)

    for tag, with_headers in (("dse_manual", True), ("dse_auto", False)):
        with tempfile.TemporaryDirectory() as td:
            cluster = LocalCluster(Path(td), group_commit_interval=0.01)
            kv = cluster.add("kv", lambda: SpeculativeKVStore(Path(td) / "kv"))
            try:
                lat, thr = _bench_ops(kv, n, with_headers=with_headers)
                s = summarize(f"instr/{tag}", lat)
                s["ops_per_s"] = round(thr)
                rows.append(s)
            finally:
                cluster.shutdown()

    base = rows[0]["ops_per_s"]
    base_us = 1e6 / base
    for r in rows[1:]:
        r["throughput_vs_no_dse"] = round(r["ops_per_s"] / base, 3)
        # The paper measures against a gRPC+FASTER stack (~0.2-1 ms/op);
        # in-process the baseline op is a dict hit, so report the ADDED
        # microseconds and what fraction of a 200us RPC-stack op that is —
        # that is the apples-to-apples form of the paper's "<5%" claim.
        added_us = 1e6 / r["ops_per_s"] - base_us
        r["added_us_per_op"] = round(added_us, 2)
        r["pct_of_200us_rpc_op"] = round(added_us / 200.0 * 100, 2)
    emit(rows, csv_path)
    return rows


if __name__ == "__main__":
    run(quick=True)
