"""Benchmark aggregator: one module per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
                                               [--json PATH]
Emits ``name,metric,value`` CSV lines (and appends to results/bench.csv).
``--json`` additionally writes ``{suite: {"row.metric": value}}`` — the
machine-readable shape committed as BENCH_PR<N>.json baselines and diffed
by ``benchmarks/compare.py`` in the CI perf-smoke step.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from . import common

SUITES = [
    ("travel", "bench_travel", "paper Fig. 9"),
    ("event", "bench_event", "paper Fig. 10"),
    ("2pc", "bench_2pc", "paper Fig. 11"),
    ("recovery", "bench_recovery", "paper Figs. 12/13"),
    ("instrumentation", "bench_instrumentation", "paper Fig. 14"),
    ("primitives", "bench_primitives", "paper Fig. 15"),
    ("training", "bench_training_dse", "beyond-paper: DSE training loop"),
    ("net", "bench_net", "beyond-paper: transport fabric + sharded coordinator"),
    ("sim", "bench_sim", "beyond-paper: deterministic simulation scheduler"),
    ("coordinator", "bench_coordinator", "beyond-paper: O(delta) coordinator hot path"),
    ("eval", "bench_eval", "paper §6.1: DSE vs durable baseline across services/persistence"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="longer runs")
    ap.add_argument("--only", action="append", default=None,
                    help="run only these suite(s); repeatable")
    ap.add_argument("--csv", default="results/bench.csv")
    ap.add_argument("--json", default=None, help="write suite→metric→value JSON")
    args = ap.parse_args()

    csv_path = Path(args.csv)
    csv_path.parent.mkdir(parents=True, exist_ok=True)

    import importlib

    failures = 0
    results = {}
    for name, module, figure in SUITES:
        if args.only and name not in args.only:
            continue
        print(f"=== {name} ({figure}) ===", flush=True)
        t0 = time.time()
        common.take_collected()  # drop rows from a failed prior suite
        try:
            mod = importlib.import_module(f"benchmarks.{module}")
            mod.run(quick=not args.full, csv_path=str(csv_path))
            results[name] = {
                f"{r['name']}.{k}": v
                for r in common.take_collected()
                for k, v in r.items()
                if k != "name"
            }
        except Exception as e:  # keep going; report at the end
            failures += 1
            print(f"FAILED {name}: {e!r}", flush=True)
        print(f"--- {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(results, indent=2, sort_keys=True) + "\n")
        print(f"wrote {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
