"""TravelReservations end-to-end app (paper §6.1, Fig. 9): a speculative
workflow engine orchestrating hotel/flight/car reservations over
speculative KV stores — with a mid-workflow service crash that rolls back
partial reservations (saga without compensations!) and a resumed run.

Run:  PYTHONPATH=src python examples/travel_reservations.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, "src")

from repro.core import LocalCluster
from repro.services import SpeculativeKVStore, WorkflowEngine

SERVICES = ["hotel", "flight", "car"]


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        with LocalCluster(root, group_commit_interval=0.010) as cluster:
            kvs = {}
            for name in SERVICES:
                kv = cluster.add(name, (lambda n=name: SpeculativeKVStore(root / n)))
                kv.stock("seat", 5)
                # make the initial inventory durable
                assert kv.StartAction(None) and kv.wait_durable(timeout=5.0)
                kv.EndAction()
                kvs[name] = kv
            wf = cluster.add("wf", lambda: WorkflowEngine(root / "wf"))

            def steps(wf_id):
                return [
                    (lambda hdr, n=n: cluster.get(n).try_reserve("seat", wf_id, hdr))
                    for n in SERVICES
                ]

            # happy path: one barrier at the END hides all speculation
            t0 = time.perf_counter()
            results, _ = wf.run_workflow("trip-1", steps("trip-1"))
            ms = (time.perf_counter() - t0) * 1e3
            print(f"trip-1 reserved {results} in {ms:.1f} ms "
                  f"(one group-commit wait, not one per service)")

            # inject a crash: flight service dies with a SPECULATIVE
            # reservation for trip-2 in memory
            out = wf.run_workflow("trip-2", steps("trip-2"), external=False)
            assert out is not None
            cluster.kill("flight")
            cluster.refresh_all()
            inv = {n: cluster.get(n).get("inv:seat")[0] for n in SERVICES}
            print(f"after flight crash, inventories={inv} — trip-2's partial "
                  f"reservations were rolled back everywhere (no compensation code)")

            # the driver resumes trip-2; control flow was part of state
            results2 = wf.run_workflow("trip-2", steps("trip-2"))
            assert results2 is not None
            inv = {n: cluster.get(n).get("inv:seat")[0] for n in SERVICES}
            print(f"trip-2 resumed and completed: {results2[0]}, inventories={inv}")


if __name__ == "__main__":
    main()
