"""Quickstart: the paper's running example (frontend -> counter -> log).

Shows the core DSE lifecycle in ~60 lines: speculative actions, dependency
headers, a speculation barrier before externalizing, and a failure that
rolls back every affected component — exactly once, transparently.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

from repro.core import LocalCluster
from repro.services.counter import CounterStateObject


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        # group_commit_interval=10ms: persistence runs in the background,
        # OFF the critical path (the paper's headline trade).
        with LocalCluster(root, group_commit_interval=0.010) as cluster:
            counter = cluster.add("counter", lambda: CounterStateObject(root / "c"))
            log = cluster.add("log", lambda: CounterStateObject(root / "l"))

            # 1) speculative request chain: counter -> log, linked by headers
            value, hdr = counter.increment(None)
            log.increment(hdr)  # log's state now DEPENDS on counter@1
            print(f"[speculative] counter={value}, log recorded it "
                  f"(nothing persisted yet)")

            # 2) externalize safely: barrier until the observed state is
            #    inside the recoverable boundary (cannot be rolled back)
            assert counter.StartAction(None)
            assert counter.wait_durable(timeout=5.0)
            counter.EndAction()
            print(f"[barrier]     counter={counter.value} is now durable — "
                  f"safe to answer an external client")

            # 3) more speculative work... then a crash
            counter.increment(None)
            counter.increment(None)
            print(f"[speculative] counter={counter.value} (2 increments in flight)")
            counter2 = cluster.kill("counter")   # crash + auto-restart
            cluster.refresh_all()                # deliver the rollback decision
            print(f"[recovered]   counter={counter2.value} — rolled back to the "
                  f"consistent durable prefix; log world={log.runtime.world}")

            # 4) stale messages from the rolled-back epoch are discarded
            assert counter2.increment(hdr) is None or True  # old-epoch header
            v, _ = counter2.increment(None)
            print(f"[resumed]     counter={v} — execution continues seamlessly")


if __name__ == "__main__":
    main()
