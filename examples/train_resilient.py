"""End-to-end resilient training driver (the TPU-fleet instantiation of
durable execution): trains a reduced-config model for N steps with async
speculative checkpointing, kills the trainer mid-run, and verifies the
final parameters are BIT-IDENTICAL to a failure-free run.

Run:  PYTHONPATH=src python examples/train_resilient.py [--arch gemma-2b] [--steps 12]
(any of the 10 assigned archs works via --arch; reduced configs on CPU)
"""
import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.train import run_resilient_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--delta-codec", action="store_true")
    args = ap.parse_args()
    kill_at = args.kill_at if args.kill_at is not None else args.steps // 2

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} (reduced config, family={cfg.family}), "
          f"steps={args.steps}, trainer killed after step {kill_at}")

    with tempfile.TemporaryDirectory() as td:
        base = run_resilient_training(Path(td) / "base", cfg, steps=args.steps)
        inj = run_resilient_training(
            Path(td) / "inj", cfg, steps=args.steps,
            kill_trainer_at=kill_at, use_delta_codec=args.delta_codec,
        )

    print(f"failure-free : digest={base.params_digest} "
          f"losses[{len(base.external_metrics)}]")
    print(f"with failure : digest={inj.params_digest} "
          f"losses[{len(inj.external_metrics)}] rollbacks={inj.rollbacks} "
          f"ckpt_bytes={inj.checkpoint_bytes}")
    same = base.params_digest == inj.params_digest
    print(f"bit-identical parameters after rollback recovery: {same}")
    losses = [l for _, l in inj.external_metrics]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} (externally visible "
          f"metrics saw every step exactly once)")
    assert same


if __name__ == "__main__":
    main()
