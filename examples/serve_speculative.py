"""Speculative serving: decode tokens from a reduced-config model where the
generated text is exported to the client only behind a speculation barrier
(failure transparency), while the KV-cache session state persists
asynchronously via a StateObject.

Run:  PYTHONPATH=src python examples/serve_speculative.py [--arch yi-6b]
"""
import argparse
import io
import sys
import tempfile
import threading
from pathlib import Path

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import LocalCluster, StateObject, VersionStore
from repro.models import cache_descs, decode_step, init_params, param_descs
from repro.models.params import is_desc


class SessionStateObject(StateObject):
    """Decode-session state (generated tokens + step) as a StateObject; the
    KV cache is derived state, rebuilt by replaying tokens on restore."""

    def __init__(self, root: Path):
        super().__init__()
        self.store = VersionStore(root)
        self.tokens = []

    def Persist(self, version, metadata, callback):
        payload = np.asarray(self.tokens, np.int32).tobytes()

        def _io():
            try:
                self.store.write(version, payload, metadata)
            except RuntimeError:
                return
            callback()

        threading.Thread(target=_io, daemon=True).start()

    def Restore(self, version):
        payload, meta = self.store.read(version)
        self.tokens = list(np.frombuffer(payload, np.int32))
        return meta

    def ListVersions(self):
        return self.store.list_versions()

    def on_crash(self):
        self.store.poison()
        self.store.drop_memory()
        self.tokens = []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_params(param_descs(cfg), jax.random.key(0), jnp.float32)
    cdescs = cache_descs(cfg, batch=1, max_len=64)
    cache = jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape, jnp.float32), cdescs, is_leaf=is_desc
    )
    extras = {}
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.ones((1, cfg.num_image_tokens, cfg.d_model)) * 0.01
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i, extras=extras))

    with tempfile.TemporaryDirectory() as td:
        with LocalCluster(Path(td), group_commit_interval=0.010) as cluster:
            sess = cluster.add("session", lambda: SessionStateObject(Path(td) / "s"))
            tok = jnp.zeros((1, 1), jnp.int32)
            emitted = 0
            for i in range(args.tokens):
                assert sess.StartAction(None)
                logits, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
                tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
                sess.tokens.append(int(tok[0, 0]))
                sess.EndAction()
                # stream to the client only what survives any failure:
                if (i + 1) % 4 == 0:
                    assert sess.StartAction(None)
                    assert sess.wait_durable(timeout=5.0)
                    sess.EndAction()
                    print(f"[client] tokens[{emitted}:{i+1}] = "
                          f"{sess.tokens[emitted:i+1]} (non-speculative)")
                    emitted = i + 1
            print(f"served {args.tokens} tokens from {cfg.name} "
                  f"(reduced config, family={cfg.family})")


if __name__ == "__main__":
    main()
